//! Deterministic, dependency-free PRNG (SplitMix64 + xoshiro256**) with the
//! distributions the simulator needs.
//!
//! The vendored crate set has no `rand` facade (DESIGN.md §Environment
//! deviations); everything stochastic in LUMOS — synthetic corpora, MoE
//! routing draws in the coordinator, netsim workloads, property-test
//! generators — flows through this module so runs are reproducible from a
//! single `u64` seed.

/// SplitMix64: used for seeding and as a cheap standalone stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the main generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (e.g. per worker / per layer).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(f64::MIN_POSITIVE), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Zipf-like draw over [0, n): P(i) ∝ 1/(i+1)^alpha. Used to model the
    /// skewed expert-popularity distributions that stress EP all-to-all.
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over the (small) support; n is expert count (≤ 1024).
        let mut total = 0.0;
        for i in 0..n {
            total += 1.0 / ((i + 1) as f64).powf(alpha);
        }
        let mut u = self.f64() * total;
        for i in 0..n {
            u -= 1.0 / ((i + 1) as f64).powf(alpha);
            if u <= 0.0 {
                return i;
            }
        }
        n - 1
    }

    /// Sample an index from unnormalized weights.
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_to_head() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 8];
        for _ in 0..8000 {
            counts[r.zipf(8, 1.2)] += 1;
        }
        assert!(counts[0] > counts[7] * 3, "{counts:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(6);
        let mut hit = 0;
        for _ in 0..5000 {
            if r.choice_weighted(&[9.0, 1.0]) == 0 {
                hit += 1;
            }
        }
        assert!(hit > 4200, "{hit}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut base = Rng::new(7);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
