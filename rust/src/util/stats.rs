//! Summary statistics and histograms for benches, netsim and the trainer.

/// Running summary of a stream of f64 samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(f64::total_cmp);
        let rank = (q / 100.0) * (xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            let frac = rank - lo as f64;
            xs[lo] * (1.0 - frac) + xs[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bucket histogram over [lo, hi); out-of-range clamps to edge buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Self { lo, hi, counts: vec![0; buckets] }
    }

    pub fn add(&mut self, x: f64) {
        let n = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * n as f64).floor();
        let idx = (t.max(0.0) as usize).min(n - 1);
        self.counts[idx] += 1;
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Compact ASCII sparkline (for bench/trainer logs).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1);
        self.counts
            .iter()
            .map(|&c| GLYPHS[(c * 7 / max) as usize])
            .collect()
    }
}

/// Format a byte count or rate with binary-ish engineering units.
pub fn fmt_si(value: f64, unit: &str) -> String {
    let prefixes = [
        (1e15, "P"),
        (1e12, "T"),
        (1e9, "G"),
        (1e6, "M"),
        (1e3, "k"),
    ];
    for (scale, p) in prefixes {
        if value.abs() >= scale {
            return format!("{:.2} {}{}", value / scale, p, unit);
        }
    }
    format!("{:.2} {}", value, unit)
}

/// Format seconds adaptively (ns/µs/ms/s/min/h/days).
pub fn fmt_time(secs: f64) -> String {
    let a = secs.abs();
    if a < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if a < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if a < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else if a < 120.0 {
        format!("{:.2} s", secs)
    } else if a < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else if a < 48.0 * 3600.0 {
        format!("{:.1} h", secs / 3600.0)
    } else {
        format!("{:.1} days", secs / 86400.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.5);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from_slice(&[0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn percentile_on_singleton() {
        let s = Summary::from_slice(&[7.0]);
        assert_eq!(s.p99(), 7.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn histogram_buckets_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        for x in [0.5, 1.5, 9.9, -3.0, 42.0] {
            h.add(x);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.counts()[0], 3); // 0.5, 1.5 (width=2), clamped -3.0
        assert_eq!(h.counts()[4], 2); // 9.9 and clamped 42.0
        assert_eq!(h.sparkline().chars().count(), 5);
    }

    #[test]
    fn si_formatting() {
        assert_eq!(fmt_si(3.2e12, "b/s"), "3.20 Tb/s");
        assert_eq!(fmt_si(5.0, "J"), "5.00 J");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.5e-9 * 1000.0), "2.50 µs");
        assert!(fmt_time(90.0).ends_with(" s"));
        assert!(fmt_time(86400.0 * 40.0).ends_with("days"));
    }
}
