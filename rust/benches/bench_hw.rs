//! Bench + regeneration of the hardware design-space results: Table III,
//! Figure 7 (power @ 32 Tb/s) and Figure 8 (area @ 32 Tb/s), plus the
//! switch-package analysis of §IV.C.b.
//!
//! Run: `cargo bench --bench bench_hw`

use lumos::hw;
use lumos::sweep;
use lumos::util::bench::{black_box, Bencher};

fn main() {
    println!("=== Table III / Fig 7 / Fig 8 ===\n");
    println!("{}", sweep::table3().render());
    let (t7, c7) = sweep::fig7();
    println!("{}\n{}", t7.render(), c7.render());
    let (t8, c8) = sweep::fig8();
    println!("{}\n{}", t8.render(), c8.render());

    let sw = hw::SwitchPackage::sls_512();
    println!("## Switch feasibility (§IV.C.b)");
    for tech in [hw::lpo_dr8(), hw::cpo_2p5d(), hw::passage_interposer()] {
        println!(
            "  {:<32} {} reticles, {:.2} kW fabric optics power",
            tech.name,
            sw.reticles_needed(&tech),
            tech.power_w(sw.fabric_gbps) / 1000.0
        );
    }
    println!();

    println!("=== Timing ===");
    let mut b = Bencher::new();
    b.bench("full hw design-space sweep", || {
        black_box(sweep::fig7());
        black_box(sweep::fig8());
        black_box(sweep::table3());
    });
    // design-space scan across bandwidth points (architect's inner loop)
    b.bench_items("power model eval", 4.0 * 64.0, "eval", || {
        for tech in hw::catalog() {
            for i in 1..=64 {
                black_box(hw::PowerBreakdown::compute(&tech, 1000.0 * i as f64));
            }
        }
    });
}
