//! Bench: the flow-level network simulator — events/second on collective
//! replays at pod scale, the substrate cost of validating the analytical
//! model.
//!
//! Run: `cargo bench --bench bench_netsim`

use lumos::collectives as coll;
use lumos::netsim::{replay_schedule, Network};
use lumos::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new();

    for n in [16usize, 64, 128] {
        let net = Network::sls(n, 32_000.0, 200e-9);
        let sched = coll::ring_all_reduce_schedule(n, 256e6);
        let flows = sched.ops.len() as f64;
        b.bench_items(&format!("replay ring-allreduce n={n}"), flows, "flow", || {
            black_box(replay_schedule(&net, &sched));
        });
    }

    for n in [16usize, 64] {
        let net = Network::sls(n, 32_000.0, 200e-9);
        let sched = coll::pairwise_a2a_schedule(n, 64e6);
        let flows = sched.ops.len() as f64;
        b.bench_items(&format!("replay pairwise-a2a n={n}"), flows, "flow", || {
            black_box(replay_schedule(&net, &sched));
        });
    }

    // cross-pod (the oversubscription study from examples/netsim_validate)
    let net = Network::cluster(64, 16, 14_400.0, 1_600.0, 2.0, 5e-6);
    let sched = coll::pairwise_a2a_schedule(64, 64e6);
    b.bench_items("replay a2a 4x16 pods (oversubscribed)", sched.ops.len() as f64, "flow", || {
        black_box(replay_schedule(&net, &sched));
    });
}
