//! Bench: the flow-level network simulator — events/second on collective
//! replays at pod scale, the substrate cost of validating the analytical
//! model. Every case runs twice where the reference is tractable: `ref` is
//! the original full-recompute progressive filling ([`simulate_reference`]
//! / [`simulate_dag_reference`]), `inc` the incremental component-local
//! engine behind [`simulate`]/[`replay_schedule`]/[`simulate_dag`] — the
//! before/after pairs for the netsim fast-path optimisations.
//!
//! The dependency-engine series lower real timeline step DAGs (the §VI
//! paper mapping, plus a deep-PP × fine-microbatch mapping from the region
//! `timeline::MAX_DAG_NODES` used to reject) — the workload whose cost
//! decides whether simulation can sit inside the planner's search loop.
//!
//! On exit the run writes a machine-readable baseline
//! (`BENCH_netsim.json`, path override via `LUMOS_BENCH_JSON`) with every
//! series plus the derived inc-vs-ref speedups, so the perf trajectory is
//! recorded run over run.
//!
//! Run: `cargo bench --bench bench_netsim`

use lumos::collectives as coll;
use lumos::model::{MoeConfig, Workload};
use lumos::netsim::{
    replay_schedule, replay_schedule_dependent, simulate, simulate_dag, simulate_dag_reference,
    simulate_dag_scan, simulate_reference, Flow, Network,
};
use lumos::parallel::{Mapping, Parallelism};
use lumos::perf::PerfKnobs;
use lumos::timeline::{lower_step, SkeletonCache};
use lumos::topology::cluster::Cluster;
use lumos::util::bench::{black_box, Bencher};
use lumos::util::json::Json;

/// Multi-step schedule whose steps touch disjoint rank groups — the case
/// where bulk-synchronous barriers serialize work the dependency engine
/// overlaps (ISSUE 3: quantifies the schedule-level pipelining win).
fn disjoint_step_schedule(n: usize, group: usize, bytes: f64) -> coll::CommSchedule {
    let mut ops = Vec::new();
    for (step, base) in (0..n).step_by(group).enumerate() {
        for i in 0..group / 2 {
            ops.push(coll::CommOp {
                step,
                src: base + 2 * i,
                dst: base + 2 * i + 1,
                bytes,
            });
        }
    }
    coll::CommSchedule::new("disjoint-steps", n, ops)
}

/// Replay a schedule through the reference (full-recompute) simulator.
fn replay_reference(net: &Network, sched: &coll::CommSchedule) -> f64 {
    let mut total = 0.0;
    for step in 0..sched.n_steps() {
        let flows: Vec<Flow> = sched
            .ops
            .iter()
            .filter(|o| o.step == step && o.src != o.dst)
            .map(|o| net.flow(o.src, o.dst, o.bytes))
            .collect();
        if !flows.is_empty() {
            total += simulate_reference(net, &flows).makespan;
        }
    }
    total
}

/// Staggered many-event batch: uneven flow sizes over shared links, so
/// completions cascade one at a time — the worst case for full recompute.
fn staggered_batch(net: &Network, n: usize) -> Vec<Flow> {
    let mut flows = Vec::new();
    for s in 0..n {
        for d in 0..n {
            if s != d {
                flows.push(net.flow(s, d, 1e6 * (1 + (s * 13 + d * 7) % 17) as f64));
            }
        }
    }
    flows
}

fn main() {
    let mut b = Bencher::new();

    for n in [16usize, 64, 128] {
        let net = Network::sls(n, 32_000.0, 200e-9);
        let sched = coll::ring_all_reduce_schedule(n, 256e6);
        let flows = sched.ops.len() as f64;
        b.bench_items(&format!("replay ring-allreduce n={n} (ref)"), flows, "flow", || {
            black_box(replay_reference(&net, &sched));
        });
        b.bench_items(&format!("replay ring-allreduce n={n} (inc)"), flows, "flow", || {
            black_box(replay_schedule(&net, &sched));
        });
    }

    for n in [16usize, 64] {
        let net = Network::sls(n, 32_000.0, 200e-9);
        let sched = coll::pairwise_a2a_schedule(n, 64e6);
        let flows = sched.ops.len() as f64;
        b.bench_items(&format!("replay pairwise-a2a n={n} (ref)"), flows, "flow", || {
            black_box(replay_reference(&net, &sched));
        });
        b.bench_items(&format!("replay pairwise-a2a n={n} (inc)"), flows, "flow", || {
            black_box(replay_schedule(&net, &sched));
        });
    }

    // cross-pod (the oversubscription study from examples/netsim_validate)
    let net = Network::cluster(64, 16, 14_400.0, 1_600.0, 2.0, 5e-6);
    let sched = coll::pairwise_a2a_schedule(64, 64e6);
    let nflows = sched.ops.len() as f64;
    b.bench_items("replay a2a 4x16 pods oversub (ref)", nflows, "flow", || {
        black_box(replay_reference(&net, &sched));
    });
    b.bench_items("replay a2a 4x16 pods oversub (inc)", nflows, "flow", || {
        black_box(replay_schedule(&net, &sched));
    });

    // dependency-driven vs bulk-synchronous replay on disjoint steps: the
    // before/after pair for schedule-level pipelining. `bulk` pays one
    // barrier per step; `dep` admits every step's flows at t=0, so the
    // *simulated* makespan collapses by ~n_steps (printed below) while the
    // wall-clock cost stays in the same ballpark.
    let net = Network::sls(64, 32_000.0, 200e-9);
    let sched = disjoint_step_schedule(64, 4, 256e6);
    let nflows = sched.ops.len() as f64;
    b.bench_items("replay disjoint 16 steps (bulk)", nflows, "flow", || {
        black_box(replay_schedule(&net, &sched));
    });
    b.bench_items("replay disjoint 16 steps (dep)", nflows, "flow", || {
        black_box(replay_schedule_dependent(&net, &sched));
    });
    let bulk = replay_schedule(&net, &sched).makespan;
    let dep = replay_schedule_dependent(&net, &sched).makespan;
    println!(
        "  simulated makespan: bulk {bulk:.6}s vs dep {dep:.6}s ({:.1}x pipelining win)",
        bulk / dep
    );

    // degraded-fabric replay (resilience fail-in-place): the same
    // collective on a healthy fabric vs one with a single GPU's uplinks at
    // half capacity (~1% of the 128-GPU fabric) — pins the cost of
    // degraded re-simulation and prints the simulated slowdown the
    // max-min barrier structure produces.
    let n = 128;
    let healthy = Network::sls(n, 32_000.0, 200e-9);
    let mut degraded = healthy.clone();
    degraded.scale_node_links(0, 0.5, 1.0);
    let sched = coll::ring_all_reduce_schedule(n, 256e6);
    let nflows = sched.ops.len() as f64;
    b.bench_items("replay ring-allreduce n=128 (healthy)", nflows, "flow", || {
        black_box(replay_schedule(&healthy, &sched));
    });
    b.bench_items("replay ring-allreduce n=128 (1 GPU degraded)", nflows, "flow", || {
        black_box(replay_schedule(&degraded, &sched));
    });
    let h = replay_schedule(&healthy, &sched).makespan;
    let d = replay_schedule(&degraded, &sched).makespan;
    println!(
        "  simulated makespan: healthy {h:.6}s vs degraded {d:.6}s ({:.2}x slowdown)",
        d / h
    );

    // staggered completions: one event per flow, the O(events × links)
    // pathology the incremental engine removes
    for n in [32usize, 64] {
        let net = Network::cluster(n, 8, 14_400.0, 1_600.0, 2.0, 0.0);
        let flows = staggered_batch(&net, n);
        let nf = flows.len() as f64;
        b.bench_items(&format!("staggered mesh n={n} (ref)"), nf, "flow", || {
            black_box(simulate_reference(&net, &flows));
        });
        b.bench_items(&format!("staggered mesh n={n} (inc)"), nf, "flow", || {
            black_box(simulate(&net, &flows));
        });
    }

    // ---- dependency engine: incremental vs full-recompute oracle ----------
    // rank-local staggered replay: admissions land mid-flight, completions
    // cascade — the dep engine's general case, small enough for the oracle
    let net = Network::cluster(16, 4, 800.0, 100.0, 2.0, 5e-6);
    let mut ops = Vec::new();
    for step in 0..8usize {
        for s in 0..16usize {
            let d = (s * 5 + step * 3 + 1) % 16;
            ops.push(coll::CommOp {
                step,
                src: s,
                dst: d,
                bytes: 1e6 * (1 + (s * 7 + d * 3 + step) % 11) as f64,
            });
        }
    }
    let sched = coll::CommSchedule::new("staggered-dep", 16, ops);
    let dag = lumos::netsim::schedule_rank_dag(&sched);
    let nn = dag.len() as f64;
    b.bench_items("dep staggered replay (ref)", nn, "node", || {
        black_box(simulate_dag_reference(&net, &dag));
    });
    b.bench_items("dep staggered replay (inc)", nn, "node", || {
        black_box(simulate_dag(&net, &dag));
    });

    // the §VI paper-mapping step DAG (~18k nodes): the workload `lumos
    // validate` and the resilience degraded re-simulation pay per call —
    // the headline inc-vs-ref pair (BENCH_netsim.json `derived` block).
    // `inc` is the lazy completion-time heap engine; `scan` is the PR 5
    // incremental engine with the per-event O(active) dt scan, kept as the
    // heap's own before/after baseline.
    let knobs = PerfKnobs::default();
    let w = Workload::paper_gpt_4p7t(4);
    let cluster = Cluster::passage_512(32_768);
    let paper = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(4));
    let step = lower_step(&w, &cluster, &paper, &knobs).expect("paper mapping lowers");
    let nn = step.nodes.len() as f64;
    b.bench_items("dep step-dag paper 18k (ref)", nn, "node", || {
        black_box(simulate_dag_reference(&step.net, &step.nodes));
    });
    b.bench_items("dep step-dag paper 18k (scan)", nn, "node", || {
        black_box(simulate_dag_scan(&step.net, &step.nodes));
    });
    b.bench_items("dep step-dag paper 18k (inc)", nn, "node", || {
        black_box(simulate_dag(&step.net, &step.nodes));
    });

    // deep-PP × fine-microbatch (~229k nodes, estimate 305k — from the
    // region the old MAX_DAG_NODES=300k cap rejected): the large-DAG
    // before/after pair. Hundreds of flows stay concurrently active across
    // 64 stages, so the reference pays a full allocation-heavy recompute
    // per event while the incremental engine re-fills only the touched
    // stage's component.
    let deep = Mapping::try_with_microbatch(
        Parallelism { tp: 8, pp: 64, dp: 64 },
        MoeConfig::paper_config(4),
        1,
    )
    .unwrap();
    let step_deep = lower_step(&w, &cluster, &deep, &knobs).expect("deep mapping lowers");
    let nn = step_deep.nodes.len() as f64;
    b.bench_items("dep step-dag deep-pp (ref)", nn, "node", || {
        black_box(simulate_dag_reference(&step_deep.net, &step_deep.nodes));
    });
    b.bench_items("dep step-dag deep-pp (scan)", nn, "node", || {
        black_box(simulate_dag_scan(&step_deep.net, &step_deep.nodes));
    });
    b.bench_items("dep step-dag deep-pp (inc)", nn, "node", || {
        black_box(simulate_dag(&step_deep.net, &step_deep.nodes));
    });

    // ---- skeleton cache: fresh lowering vs re-parameterization ------------
    // Every cached call still pays `step_volumes` + the slot table + the
    // in-place value rewrite; only skeleton construction is amortized —
    // the per-candidate lowering cost inside `plan --objective sim`.
    b.bench_items("lower deep-pp (fresh)", nn, "node", || {
        black_box(lower_step(&w, &cluster, &deep, &knobs).expect("deep mapping lowers"));
    });
    let mut cache = SkeletonCache::new();
    cache.lower(&w, &cluster, &deep, &knobs).expect("deep mapping lowers");
    b.bench_items("lower deep-pp (cached)", nn, "node", || {
        black_box(cache.lower(&w, &cluster, &deep, &knobs).expect("deep mapping lowers"));
    });

    // ---- per-candidate scoring: the PR 5 path vs the PR 7 path ------------
    // What one planner candidate costs end to end: fresh lowering + dt-scan
    // event loop (how PR 5's --rerank-sim scored a plan) vs skeleton-cache
    // re-parameterization + lazy-heap simulation (the --objective sim inner
    // loop). The acceptance gate on this pair lives in `derived` below.
    b.bench_items("plan candidate deep-pp (relower+scan)", nn, "node", || {
        let s = lower_step(&w, &cluster, &deep, &knobs).expect("deep mapping lowers");
        black_box(simulate_dag_scan(&s.net, &s.nodes));
    });
    let mut cache = SkeletonCache::new();
    cache.lower(&w, &cluster, &deep, &knobs).expect("deep mapping lowers");
    b.bench_items("plan candidate deep-pp (cache+heap)", nn, "node", || {
        let s = cache.lower(&w, &cluster, &deep, &knobs).expect("deep mapping lowers");
        black_box(simulate_dag(&s.net, &s.nodes));
    });

    // ---- machine-readable baseline ----------------------------------------
    let speedup = |pair: &str| -> Json {
        match (b.mean_of(&format!("{pair} (ref)")), b.mean_of(&format!("{pair} (inc)"))) {
            (Some(r), Some(i)) if i > 0.0 => Json::num(r / i),
            _ => Json::Null,
        }
    };
    let ratio = |num: &str, den: &str| -> Json {
        match (b.mean_of(num), b.mean_of(den)) {
            (Some(n), Some(d)) if d > 0.0 => Json::num(n / d),
            _ => Json::Null,
        }
    };
    let derived = Json::obj(vec![
        ("dep_staggered_speedup", speedup("dep staggered replay")),
        ("dep_step_dag_paper_speedup", speedup("dep step-dag paper 18k")),
        ("dep_step_dag_deep_speedup", speedup("dep step-dag deep-pp")),
        (
            "dep_step_dag_paper_heap_vs_scan",
            ratio("dep step-dag paper 18k (scan)", "dep step-dag paper 18k (inc)"),
        ),
        (
            "dep_step_dag_deep_heap_vs_scan",
            ratio("dep step-dag deep-pp (scan)", "dep step-dag deep-pp (inc)"),
        ),
        (
            "lowering_cache_deep_speedup",
            ratio("lower deep-pp (fresh)", "lower deep-pp (cached)"),
        ),
        (
            "plan_candidate_deep_speedup",
            ratio("plan candidate deep-pp (relower+scan)", "plan candidate deep-pp (cache+heap)"),
        ),
        ("staggered_mesh_64_speedup", speedup("staggered mesh n=64")),
        ("deep_pp_nodes", Json::num(step_deep.nodes.len() as f64)),
    ]);
    let out = Json::obj(vec![
        ("bench", Json::str("netsim")),
        ("series", b.to_json().get("series").clone()),
        ("derived", derived),
    ]);
    let path =
        std::env::var("LUMOS_BENCH_JSON").unwrap_or_else(|_| "BENCH_netsim.json".to_string());
    std::fs::write(&path, out.to_string_pretty() + "\n").expect("write bench baseline");
    println!("  baseline written to {path}");
}
