//! Bench + regeneration of Tables I–IV: prints the paper's rows and times
//! the generation path (the sweep engine must stay fast enough for
//! interactive design-space exploration).
//!
//! Run: `cargo bench --bench bench_tables`

use lumos::sweep;
use lumos::util::bench::{black_box, Bencher};

fn main() {
    println!("=== Regenerated paper tables ===\n");
    for t in [sweep::table1(), sweep::table2(), sweep::table3(), sweep::table4()] {
        println!("{}", t.render());
    }

    println!("=== Generation timing ===");
    let mut b = Bencher::new();
    b.bench("table1..4 render", || {
        for t in [sweep::table1(), sweep::table2(), sweep::table3(), sweep::table4()] {
            black_box(t.render());
        }
    });
}
