//! Bench: coordinator components — expert router throughput (tokens/s),
//! all-to-all payload packing, and 1F1B schedule generation. These are the
//! L3 request-path operations that must never bottleneck training.
//!
//! Run: `cargo bench --bench bench_coordinator`

use lumos::coordinator::{one_f_one_b, simulate_slots, Router, RouterConfig};
use lumos::util::bench::{black_box, Bencher};
use lumos::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();

    // Router throughput at the paper's Config 4 shape (256 experts, top-8).
    let cfg = RouterConfig {
        n_experts: 256,
        top_k: 8,
        experts_per_rank: 8,
        capacity: 4096,
        max_devices_per_token: None,
        remap: None,
    };
    let router = Router::new(cfg);
    let mut rng = Rng::new(1);
    let n_tokens = 8192;
    let choices = router.synthetic_choices(n_tokens, 1.1, &mut rng);
    b.bench_items(&format!("route {} tokens, E=256 k=8", n_tokens), n_tokens as f64, "tok", || {
        black_box(router.route(&choices));
    });

    // device-limited routing (the restricted baseline) for comparison
    let mut cfg_lim = router.cfg.clone();
    cfg_lim.max_devices_per_token = Some(4);
    let router_lim = Router::new(cfg_lim);
    b.bench_items("route (device-limited M=4)", n_tokens as f64, "tok", || {
        black_box(router_lim.route(&choices));
    });

    // payload packing for the all-to-all
    let routed = router.route(&choices);
    let d = 64;
    let feats: Vec<Vec<f32>> = (0..n_tokens).map(|t| vec![t as f32; d]).collect();
    b.bench_items("pack a2a payloads (64-dim)", routed.assignments.len() as f64, "tok", || {
        black_box(router.pack_a2a(&routed, &feats));
    });

    // 1F1B schedule generation + timing simulation
    b.bench("1F1B schedule gen (pp=8, m=16) x 1000", || {
        for _ in 0..1000 {
            for s in 0..8 {
                black_box(one_f_one_b(8, s, 16));
            }
        }
    });
    b.bench("1F1B slot simulation (pp=8, m=64)", || {
        black_box(simulate_slots(8, 64));
    });
}
