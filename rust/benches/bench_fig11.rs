//! Bench + regeneration of Figure 11: system-specific radix comparison —
//! Passage (512 @ 32 Tb/s) vs the electrical alternative (144 @ 14.4 Tb/s).
//! This is the paper's headline: 1.6× (Config 1) growing to 2.7× (Config 4)
//! as expert all-to-all spills onto the scale-out network.
//!
//! Run: `cargo bench --bench bench_fig11`

use lumos::perf::{evaluate_paper_config, paper_clusters, PerfKnobs};
use lumos::sweep;
use lumos::util::bench::{black_box, Bencher};

fn main() {
    let knobs = PerfKnobs::default();
    let (t, chart) = sweep::fig11(&knobs);
    println!("{}\n{}", t.render(), chart.render());
    println!("{}", sweep::breakdown_table(&knobs).render());
    println!("paper reference: 1.6x (Config 1) -> 2.7x (Config 4).\n");

    println!("=== Engine timing ===");
    let (passage, _, alt144) = paper_clusters();
    let mut b = Bencher::new();
    b.bench_items("fig11 full evaluation (8 model evals)", 8.0, "eval", || {
        for i in 1..=4 {
            black_box(evaluate_paper_config(&passage, i, &knobs));
            black_box(evaluate_paper_config(&alt144, i, &knobs));
        }
    });
    // The sweep engine's interactive workload: a full ablation suite,
    // serial vs pooled.
    b.bench("ablation suite (pod+bw+granularity) --jobs 1", || {
        black_box(sweep::pod_size_sweep_par(&knobs, 1));
        black_box(sweep::bandwidth_sweep_par(&knobs, 1));
        black_box(sweep::granularity_sweep_par(&knobs, 1));
    });
    b.bench("ablation suite (pod+bw+granularity) --jobs 4", || {
        black_box(sweep::pod_size_sweep_par(&knobs, 4));
        black_box(sweep::bandwidth_sweep_par(&knobs, 4));
        black_box(sweep::granularity_sweep_par(&knobs, 4));
    });
}
