//! Bench: the PJRT runtime hot path — real AOT-compiled training-step
//! executions (tiny preset) and the host<->literal marshalling around them.
//! Skipped (with a note) when artifacts are missing.
//!
//! Run: `cargo bench --bench bench_runtime`
//! (requires `make artifacts`)

use lumos::runtime::{artifacts_root, Artifact, Engine, Tensor};
use lumos::util::bench::{black_box, Bencher};
use lumos::util::rng::Rng;

fn main() {
    let Ok(root) = artifacts_root() else {
        println!("SKIP bench_runtime: artifacts/ missing (run `make artifacts`)");
        return;
    };
    let Ok(art) = Artifact::load(root.join("tiny")) else {
        println!("SKIP bench_runtime: artifacts/tiny missing");
        return;
    };
    let engine = Engine::cpu().expect("pjrt cpu client");
    let init = engine.load(&art, "init").expect("compile init");
    let train = engine.load(&art, "train_step").expect("compile train_step");
    let fwd = engine.load(&art, "forward").expect("compile forward");

    let batch = art.cfg_usize("batch").unwrap();
    let seq = art.cfg_usize("seq_len").unwrap();
    let vocab = art.cfg_usize("vocab").unwrap();
    let mut rng = Rng::new(7);
    let tokens = Tensor::I32(
        (0..batch * (seq + 1)).map(|_| rng.below(vocab as u64) as i32).collect(),
        vec![batch, seq + 1],
    );
    let state = init.execute(&[Tensor::scalar_u32(0)]).unwrap();

    let mut b = Bencher::new();
    let toks_per_step = (batch * seq) as f64;

    let state2 = state.clone();
    let tokens2 = tokens.clone();
    b.bench_items("train_step (tiny, fused)", toks_per_step, "tok", move || {
        let mut inputs = state2.clone();
        inputs.push(tokens2.clone());
        black_box(train.execute(&inputs).unwrap());
    });

    let params: Vec<Tensor> = state[..art.n_params].to_vec();
    let fwd_tokens = Tensor::I32(
        (0..batch * seq).map(|_| rng.below(vocab as u64) as i32).collect(),
        vec![batch, seq],
    );
    b.bench_items("forward (tiny)", toks_per_step, "tok", move || {
        let mut inputs = params.clone();
        inputs.push(fwd_tokens.clone());
        black_box(fwd.execute(&inputs).unwrap());
    });

    // marshalling cost in isolation: Tensor -> Literal -> Tensor
    let big = Tensor::F32(vec![1.0; 1 << 20], vec![1 << 20]);
    b.bench_items("literal roundtrip 4 MB", (4 << 20) as f64, "B", || {
        let lit = big.to_literal().unwrap();
        black_box(Tensor::from_literal(&lit).unwrap());
    });

    let st = init.stats();
    println!("\ninit entry stats: {} executions, {:.3}s total", st.executions, st.total_secs);
}
