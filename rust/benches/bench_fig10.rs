//! Bench + regeneration of Figure 10: same-radix (512) comparison,
//! Passage 32 Tb/s vs the electrical alternative at 14.4 Tb/s, isolating
//! the bandwidth effect. Prints the paper's series and times the
//! analytical engine.
//!
//! Run: `cargo bench --bench bench_fig10`

use lumos::perf::{evaluate_paper_config, paper_clusters, PerfKnobs};
use lumos::sweep;
use lumos::util::bench::{black_box, Bencher};

fn main() {
    let knobs = PerfKnobs::default();
    let (t, chart) = sweep::fig10(&knobs);
    println!("{}\n{}", t.render(), chart.render());
    println!("paper reference: Alt/Passage = 1.4x (C1, C2) and 1.3x (C3, C4);");
    println!("                 Passage C4 = 1.02x its own C1.\n");

    println!("=== Engine timing ===");
    let (passage, alt512, _) = paper_clusters();
    let mut b = Bencher::new();
    b.bench_items("fig10 full evaluation (8 model evals)", 8.0, "eval", || {
        for i in 1..=4 {
            black_box(evaluate_paper_config(&passage, i, &knobs));
            black_box(evaluate_paper_config(&alt512, i, &knobs));
        }
    });
    // the sweep engine path, serial vs pooled (deterministic output either way)
    b.bench("fig10 via sweep engine --jobs 1", || {
        black_box(sweep::fig10_par(&knobs, 1));
    });
    b.bench("fig10 via sweep engine --jobs 4", || {
        black_box(sweep::fig10_par(&knobs, 4));
    });
}
