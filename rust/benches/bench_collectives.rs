//! Bench: the coordinator's real collectives (ring all-reduce, all-gather,
//! pairwise all-to-all over worker threads) — the L3 hot path of the
//! miniature training runtime — plus the closed-form model evaluation rate.
//!
//! Run: `cargo bench --bench bench_collectives`

use lumos::collectives as coll;
use lumos::coordinator::run_workers;
use lumos::topology::cluster::DomainSpec;
use lumos::util::bench::{black_box, Bencher};

fn bench_real_allreduce(b: &mut Bencher, n_workers: usize, elems: usize) {
    let bytes = (n_workers * elems * 4) as f64;
    b.bench_items(
        &format!("rust ring all-reduce {}x{}KB", n_workers, elems * 4 / 1024),
        bytes,
        "B",
        || {
            let out = run_workers(n_workers, move |mut ep| {
                let mut data = vec![ep.rank as f32; elems];
                ep.all_reduce_sum(&mut data, 1).unwrap();
                data[0]
            });
            black_box(out);
        },
    );
}

fn bench_real_a2a(b: &mut Bencher, n_workers: usize, elems_per_peer: usize) {
    let bytes = (n_workers * n_workers * elems_per_peer * 4) as f64;
    b.bench_items(
        &format!("rust pairwise a2a {}x{}KB/peer", n_workers, elems_per_peer * 4 / 1024),
        bytes,
        "B",
        || {
            let out = run_workers(n_workers, move |mut ep| {
                let chunks: Vec<Vec<f32>> =
                    (0..ep.n_ranks).map(|d| vec![d as f32; elems_per_peer]).collect();
                ep.all_to_all(chunks, 1).unwrap().len()
            });
            black_box(out);
        },
    );
}

fn main() {
    println!("=== L3 collective engine (real threads, real payloads) ===");
    let mut b = Bencher::new();
    bench_real_allreduce(&mut b, 4, 262_144); // 1 MB per rank
    bench_real_allreduce(&mut b, 8, 262_144);
    bench_real_allreduce(&mut b, 4, 4_194_304); // 16 MB per rank
    bench_real_a2a(&mut b, 4, 65_536);
    bench_real_a2a(&mut b, 8, 65_536);

    println!("\n=== Hockney model evaluation rate (sweep inner loop) ===");
    let dom = DomainSpec {
        name: "passage".into(),
        gbps_per_gpu: 32_000.0,
        latency_s: 200e-9,
        a2a_efficiency: 0.95,
    };
    b.bench_items("closed-form collective costs", 4e6, "eval", || {
        let mut acc = 0.0;
        for i in 0..1_000_000u64 {
            let bytes = (i % 1024) as f64 * 1e3;
            acc += coll::all_reduce_time(&dom, 16, bytes);
            acc += coll::all_to_all_time(&dom, 512, bytes);
            acc += coll::all_gather_time(&dom, 144, bytes);
            acc += coll::p2p_time(&dom, bytes);
        }
        black_box(acc);
    });
}
