//! Vendored minimal `anyhow` shim (see README.md): the subset of the real
//! crate's API that LUMOS uses, with no external dependencies.
//!
//! An [`Error`] is a chain of human-readable messages, outermost context
//! first. `Display` shows the outermost message; the `{:#}` alternate form
//! shows the whole chain joined by `: `, matching real anyhow.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default type parameter as the
/// real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained dynamic error. `chain[0]` is the outermost context,
/// the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message (the `anyhow!` macro and
    /// `map_err(anyhow::Error::msg)` both land here).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a layer of context (what `.context(...)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any std error. `Error` itself intentionally does NOT
// implement `std::error::Error` (exactly like real anyhow), which is what
// keeps this blanket impl coherent next to `impl<T> From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(|| ...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    // `{:#}` so an inner `anyhow::Error` keeps its chain (other error
    // types render identically either way).
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u8> {
            let v: u8 = "256".parse()?;
            Ok(v)
        }
        let e = inner().unwrap_err();
        assert!(!e.root_cause().is_empty());
        assert_eq!(e.chain().count(), 1);
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("boom".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: boom");

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("literal {}", 42);
        assert_eq!(format!("{e}"), "literal 42");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e = Error::msg("root").context("mid").context("top");
        let d = format!("{e:?}");
        assert!(d.starts_with("top"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("root"));
    }
}
