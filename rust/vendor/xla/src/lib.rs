//! Vendored `xla` (xla_extension) API stub — see README.md.
//!
//! Host-side [`Literal`] values are fully functional (buffers, shapes,
//! tuples); the PJRT client surface exists so dependent code compiles, but
//! [`PjRtClient::cpu`] reports that no PJRT runtime is available.

use std::fmt;

/// Error type mirroring the real crate's (stringly, `std::error::Error`).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }

    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: built against the vendored xla API stub (no PJRT shared library); \
             swap rust/Cargo.toml to the real `xla` crate for execution"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types of the real XLA; only F32/S32/U32 carry data in the stub.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    F32,
    F64,
    Bf16,
}

/// Array shape: dimensions + element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Backing storage of a literal.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }

    fn ty(&self) -> Option<ElementType> {
        match self {
            Data::F32(_) => Some(ElementType::F32),
            Data::I32(_) => Some(ElementType::S32),
            Data::U32(_) => Some(ElementType::U32),
            Data::Tuple(_) => None,
        }
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
    impl Sealed for u32 {}
}

/// Rust element types that map onto stub literals.
pub trait NativeType: Copy + sealed::Sealed {
    #[doc(hidden)]
    const TY: ElementType;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn slice(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn slice(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn slice(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
    fn wrap(v: Vec<u32>) -> Data {
        Data::U32(v)
    }
    fn slice(d: &Data) -> Option<&[u32]> {
        match d {
            Data::U32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host-side literal: element buffer + dims, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Tuple literal (stub-side constructor, used by tests).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: Data::Tuple(elements) }
    }

    /// Same buffer under new dimensions; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if dims.iter().any(|&d| d < 0) {
            return Err(Error::new(format!("reshape: negative dim in {dims:?}")));
        }
        if matches!(self.data, Data::Tuple(_)) {
            return Err(Error::new("reshape: literal is a tuple"));
        }
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() {
            return Err(Error::new(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Shape of an array literal (error for tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data.ty() {
            Some(ty) => Ok(ArrayShape { dims: self.dims.clone(), ty }),
            None => Err(Error::new("array_shape: literal is a tuple")),
        }
    }

    /// Copy the buffer out as a host vector of the matching element type.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::slice(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error::new(format!("to_vec: literal is {:?}, not {:?}", self.data.ty(), T::TY)))
    }

    /// First element of the buffer (scalar fast path).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let s = T::slice(&self.data).ok_or_else(|| {
            Error::new(format!("get_first_element: literal is {:?}, not {:?}", self.data.ty(), T::TY))
        })?;
        s.first().copied().ok_or_else(|| Error::new("get_first_element: empty literal"))
    }

    /// Split a tuple literal into its elements (error for arrays, matching
    /// the real crate, whose callers treat `Err` as "not a tuple").
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.data {
            Data::Tuple(elems) => Ok(std::mem::take(elems)),
            _ => Err(Error::new("decompose_tuple: literal is not a tuple")),
        }
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (opaque in the stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle; in the stub it just wraps a literal.
pub struct PjRtBuffer(Literal);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.0.clone())
    }
}

/// PJRT client. Construction fails in the stub with a clear message.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (never constructible through the stub client).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Replica-major execution results, like the real crate.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(lit.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn scalar_reshape_to_rank0() {
        let lit = Literal::vec1(&[7u32]).reshape(&[]).unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(lit.get_first_element::<u32>().unwrap(), 7);
    }

    #[test]
    fn type_mismatch_is_error() {
        let lit = Literal::vec1(&[1i32, 2]);
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn bad_reshape_rejected() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(lit.reshape(&[2, 2]).is_err());
        assert!(lit.reshape(&[-3]).is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let elems = t.decompose_tuple().unwrap();
        assert_eq!(elems.len(), 2);
        let mut arr = Literal::vec1(&[1.0f32]);
        assert!(arr.decompose_tuple().is_err());
    }

    #[test]
    fn pjrt_is_unavailable_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
