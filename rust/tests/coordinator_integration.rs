//! Integration: the coordinator's distributed machinery driving real PJRT
//! training (tiny artifacts) plus coordinator-only composition tests that
//! need no artifacts.

use lumos::coordinator::{run_workers, Router, RouterConfig};
use lumos::runtime::{artifacts_root, Artifact, Engine};
use lumos::trainer::{train_dp, train_single};
use lumos::util::rng::Rng;

fn tiny() -> Option<Artifact> {
    let root = artifacts_root().ok()?;
    Artifact::load(root.join("tiny")).ok()
}

macro_rules! require_artifacts {
    () => {
        match tiny() {
            Some(a) => a,
            None => {
                eprintln!("SKIP: artifacts/tiny missing; run `make artifacts`");
                return;
            }
        }
    };
}

#[test]
fn single_worker_training_learns_markov_corpus() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let report = train_single(&engine, &art, 400, 42, false).unwrap();
    assert_eq!(report.steps.len(), 400);
    assert!(
        report.last_loss() < report.first_loss() * 0.85,
        "no learning: {} -> {}",
        report.first_loss(),
        report.last_loss()
    );
    // losses decrease *towards* (but can't beat) the chain entropy
    assert!(report.last_loss() > 0.3);
}

#[test]
fn dp_training_learns_and_workers_agree() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let report = train_dp(&engine, &art, 2, 15, 7, false).unwrap();
    assert_eq!(report.mode, "dp2");
    assert!(
        report.last_loss() < report.first_loss(),
        "{} -> {}",
        report.first_loss(),
        report.last_loss()
    );
    // gradients really moved through the rust fabric
    assert!(report.steps[1].comm_bytes > 100_000, "{}", report.steps[1].comm_bytes);
}

#[test]
fn dp1_is_deterministic() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let dp = train_dp(&engine, &art, 1, 6, 99, false).unwrap();
    let dp2 = train_dp(&engine, &art, 1, 6, 99, false).unwrap();
    for (a, b) in dp.steps.iter().zip(&dp2.steps) {
        assert_eq!(a.ce_loss, b.ce_loss, "nondeterministic step {}", a.step);
    }
}

#[test]
fn dp_gradient_averaging_changes_trajectory_vs_local() {
    // Two workers with different shards: the averaged trajectory must
    // differ from a single worker's local one (same init seed).
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let dp2 = train_dp(&engine, &art, 2, 4, 11, false).unwrap();
    let dp1 = train_dp(&engine, &art, 1, 4, 11, false).unwrap();
    let diverged = dp1
        .steps
        .iter()
        .zip(&dp2.steps)
        .skip(1)
        .any(|(a, b)| (a.ce_loss - b.ce_loss).abs() > 1e-6);
    assert!(diverged, "dp2 trajectory identical to dp1 — averaging is a no-op?");
}

// ----------------------------------------------------------- no-artifact

#[test]
fn router_feeds_all_to_all_consistently() {
    // Route a batch on every rank, pack payloads, exchange via the real
    // all-to-all, and verify each rank receives exactly the token count
    // every peer routed to it.
    let n_ranks = 4;
    let d = 6; // feature dim
    let results = run_workers(n_ranks, move |mut ep| {
        let cfg = RouterConfig {
            n_experts: 8,
            top_k: 2,
            experts_per_rank: 2,
            capacity: 64,
            max_devices_per_token: None,
            remap: None,
        };
        let router = Router::new(cfg);
        let mut rng = Rng::new(100 + ep.rank as u64);
        let choices = router.synthetic_choices(32, 1.0, &mut rng);
        let routed = router.route(&choices);
        let feats: Vec<Vec<f32>> = (0..32)
            .map(|t| vec![(ep.rank * 1000 + t) as f32; d])
            .collect();
        let packed = router.pack_a2a(&routed, &feats);
        let sent_to: Vec<usize> = packed.iter().map(|p| p.len() / d).collect();
        let received = ep.all_to_all(packed, 0).unwrap();
        let recv_from: Vec<usize> = received.iter().map(|p| p.len() / d).collect();
        // publish counts so rank 0 can cross-check the transpose
        let flat: Vec<f32> = sent_to.iter().chain(recv_from.iter()).map(|&x| x as f32).collect();
        ep.all_gather(&flat, 1).unwrap()
    });
    // results[0] = [rank0: sent[4] ++ recv[4], rank1: ...]
    let table = &results[0];
    let stride = 2 * n_ranks;
    for src in 0..n_ranks {
        for dst in 0..n_ranks {
            let sent = table[src * stride + dst];
            let recv = table[dst * stride + n_ranks + src];
            assert_eq!(sent, recv, "src {src} dst {dst}");
        }
    }
}

#[test]
fn pipeline_schedule_composes_with_workers() {
    // Each worker plays one pipeline stage, forwarding real messages in
    // 1F1B order; every stage must see all microbatches in order.
    use lumos::coordinator::{one_f_one_b, Action};
    let pp = 4;
    let n_micro = 6;
    let outs = run_workers(pp, move |mut ep| {
        let stage = ep.rank;
        let sched = one_f_one_b(pp, stage, n_micro);
        let mut seen = Vec::new();
        for action in sched {
            match action {
                Action::Forward(i) => {
                    let x = if stage == 0 {
                        vec![i as f32]
                    } else {
                        ep.recv(stage - 1, 10 + i as u64).unwrap()
                    };
                    seen.push(x[0] as usize);
                    if stage + 1 < pp {
                        ep.send(stage + 1, 10 + i as u64, x).unwrap();
                    }
                }
                Action::Backward(i) => {
                    let g = if stage == pp - 1 {
                        vec![i as f32]
                    } else {
                        ep.recv(stage + 1, 1000 + i as u64).unwrap()
                    };
                    if stage > 0 {
                        ep.send(stage - 1, 1000 + i as u64, g).unwrap();
                    }
                }
            }
        }
        seen
    });
    for (stage, seen) in outs.iter().enumerate() {
        assert_eq!(seen, &(0..n_micro).collect::<Vec<_>>(), "stage {stage}");
    }
}
