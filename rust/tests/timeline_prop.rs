//! Property tests for the timeline lowering path (ISSUE 7): the skeleton
//! cache's in-place re-parameterization must be *bit-equal* to a fresh
//! `lower_step` on every candidate — across (TP, PP)-sharing candidate
//! pairs (cache hits), shape changes (misses) and evictions — and the
//! simulated report of a cached lowering must match the uncached
//! `simulate_step` path exactly. This is the invariant that makes
//! per-worker caches safe in `lumos plan --objective sim`: results never
//! depend on cache state, so they never depend on which worker simulated
//! which candidate. Uses the in-tree `util::prop` framework (seeded;
//! override with `LUMOS_PROP_SEED`).

use lumos::model::{MoeConfig, Workload};
use lumos::netsim::DagWork;
use lumos::parallel::{Mapping, Parallelism};
use lumos::perf::PerfKnobs;
use lumos::prop_assert;
use lumos::timeline::{lower_step, simulate_step, simulate_step_cached, SkeletonCache, StepDag};
use lumos::topology::cluster::Cluster;
use lumos::util::prop::{check, Gen};

/// A random *valid* Passage-512 mapping: tp·pp·dp covers the 32 768 GPUs
/// and the microbatch grain divides the per-rank batch. tp/pp stay in the
/// planner's neighborhood of the paper mapping so DAGs stay mid-sized.
fn random_mapping(g: &mut Gen) -> Mapping {
    let tp = *g.choose(&[8usize, 16]);
    let pp = *g.choose(&[8usize, 16]);
    let dp = 32_768 / (tp * pp);
    let mb = *g.choose(&[1usize, 2, 4, 8]);
    Mapping::try_with_microbatch(Parallelism { tp, pp, dp }, MoeConfig::paper_config(4), mb)
        .expect("grid mappings are valid on Passage-512")
}

fn random_knobs(g: &mut Gen) -> PerfKnobs {
    PerfKnobs {
        mfu: *g.choose(&[0.3, 0.4, 0.55]),
        comm_dtype_bytes: *g.choose(&[2.0, 4.0]),
        ..PerfKnobs::default()
    }
}

fn dags_bit_equal(a: &StepDag, b: &StepDag) -> Result<(), String> {
    prop_assert!(a.nodes.len() == b.nodes.len(), "{} vs {} nodes", a.nodes.len(), b.nodes.len());
    for (i, (x, y)) in a.nodes.iter().zip(&b.nodes).enumerate() {
        prop_assert!(x.deps == y.deps, "node {i}: deps differ");
        match (&x.work, &y.work) {
            (DagWork::Delay(dx), DagWork::Delay(dy)) => {
                prop_assert!(dx.to_bits() == dy.to_bits(), "node {i}: delay {dx} vs {dy}");
            }
            (
                DagWork::Flow { src: sx, dst: dx, bytes: bx },
                DagWork::Flow { src: sy, dst: dy, bytes: by },
            ) => {
                prop_assert!((sx, dx) == (sy, dy), "node {i}: endpoints differ");
                prop_assert!(bx.to_bits() == by.to_bits(), "node {i}: bytes {bx} vs {by}");
            }
            _ => prop_assert!(false, "node {i}: kind mismatch"),
        }
    }
    prop_assert!(a.net.n_nodes == b.net.n_nodes, "network size differs");
    prop_assert!(a.chain.len() == b.chain.len(), "chain length differs");
    Ok(())
}

#[test]
fn prop_cached_lowering_is_bit_equal_to_fresh() {
    // One shared cache fed a random candidate sequence (random shapes ×
    // random knobs → a mix of hits, misses and evictions) must hand back
    // exactly what a fresh lowering builds, candidate by candidate.
    let w = Workload::paper_gpt_4p7t(4);
    let cluster = Cluster::passage_512(32_768);
    check("cache.lower == lower_step bit-for-bit", 16, |g| {
        let mut cache = SkeletonCache::new();
        for _ in 0..g.usize(2, 5) {
            let m = random_mapping(g);
            let knobs = random_knobs(g);
            let fresh = lower_step(&w, &cluster, &m, &knobs).expect("grid mapping lowers");
            let cached = cache.lower(&w, &cluster, &m, &knobs).expect("grid mapping lowers");
            dags_bit_equal(cached, &fresh)?;
        }
        Ok(())
    });
}

#[test]
fn prop_cached_simulation_matches_uncached_path() {
    // End to end: simulate_step_cached (what the sim-objective planner
    // workers run) reports the same step time as the uncached
    // simulate_step, bit for bit, on (TP, PP)-sharing candidate pairs.
    let w = Workload::paper_gpt_4p7t(4);
    let cluster = Cluster::passage_512(32_768);
    check("simulate_step_cached == simulate_step", 8, |g| {
        let mut cache = SkeletonCache::new();
        let shape = random_mapping(g);
        for _ in 0..2 {
            let knobs = random_knobs(g);
            let cached =
                simulate_step_cached(&w, &cluster, &shape, &knobs, &mut cache).expect("simulates");
            let fresh = simulate_step(&w, &cluster, &shape, &knobs).expect("simulates");
            prop_assert!(
                cached.step_time.to_bits() == fresh.step_time.to_bits(),
                "step time {} vs {}",
                cached.step_time,
                fresh.step_time
            );
        }
        Ok(())
    });
}
