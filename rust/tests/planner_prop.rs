//! Planner integration: properties of the candidate enumeration (the
//! ISSUE-2 contract — divisibility, HBM feasibility, full GPU partition,
//! paper-mapping membership), plus planner determinism across worker
//! counts.

use lumos::model::Workload;
use lumos::parallel::{enumerate_candidates, Mapping, Parallelism};
use lumos::perf::memory::memory_breakdown;
use lumos::perf::{check_feasible, PerfKnobs};
use lumos::planner::{plan, ranked_table, PlanRequest};
use lumos::prop_assert;
use lumos::sweep::engine::ClusterKey;
use lumos::topology::cluster::Cluster;
use lumos::util::prop::check;

#[test]
fn every_candidate_satisfies_divisibility_and_partitions_all_gpus() {
    check("candidate legality", 48, |g| {
        let cfg = g.usize(1, 4);
        // Power-of-two pods tile 32,768 exactly; the 144-pod case is
        // covered at the §VI cluster size 32,256 = 2^9·3^2·7 (the naive
        // 32,768-truncated size 32,688 contains the prime 227 and
        // legitimately admits no legal mapping).
        let (pod, n) = *g.choose(&[
            (64usize, 32_768usize),
            (128, 32_768),
            (144, 32_256),
            (256, 32_768),
            (512, 32_768),
        ]);
        let gbps = *g.choose(&[14_400.0, 32_000.0]);
        let cluster = ClusterKey::custom(n, pod, gbps).build();
        let w = Workload::paper_gpt_4p7t(cfg);
        let cands = enumerate_candidates(&w, &cluster);
        prop_assert!(!cands.is_empty(), "empty candidate space at pod={pod}");
        for m in &cands {
            prop_assert!(
                m.par.n_gpus() == cluster.spec.n_gpus,
                "tp{} x pp{} x dp{} != {}",
                m.par.tp,
                m.par.pp,
                m.par.dp,
                cluster.spec.n_gpus
            );
            prop_assert!(m.par.tp <= pod, "tp {} exceeds pod {pod}", m.par.tp);
            prop_assert!(w.n_heads % m.par.tp == 0, "heads % tp, tp={}", m.par.tp);
            prop_assert!(m.par.pp <= w.n_layers, "pp {} > layers", m.par.pp);
            prop_assert!(w.global_batch % m.par.dp == 0, "batch % dp, dp={}", m.par.dp);
            prop_assert!(
                (w.global_batch / m.par.dp) % m.microbatch_seqs == 0,
                "microbatch {} does not divide seqs/rank",
                m.microbatch_seqs
            );
            prop_assert!(
                Mapping::try_with_microbatch(m.par, m.moe, m.microbatch_seqs).is_ok(),
                "mapping predicate failed"
            );
            prop_assert!(
                w.d_ff_expert() % m.expert_tp() == 0,
                "expert ffn shard, expert_tp={}",
                m.expert_tp()
            );
        }
        Ok(())
    });
}

#[test]
fn feasibility_of_candidates_reduces_to_hbm_fit() {
    // Enumeration already guarantees every divisibility constraint, so on
    // emitted candidates check_feasible must agree exactly with
    // MemoryBreakdown::fits().
    check("feasible == fits", 12, |g| {
        let cfg = g.usize(1, 4);
        let cluster =
            g.choose(&[ClusterKey::Passage512, ClusterKey::Electrical144]).clone().build();
        let w = Workload::paper_gpt_4p7t(cfg);
        for m in enumerate_candidates(&w, &cluster) {
            let fits = memory_breakdown(&w, &m).fits();
            prop_assert!(
                check_feasible(&w, &m).is_ok() == fits,
                "feasibility/fits disagree at tp{} pp{} dp{} mb{}",
                m.par.tp,
                m.par.pp,
                m.par.dp,
                m.microbatch_seqs
            );
        }
        Ok(())
    });
}

#[test]
fn paper_mapping_is_an_hbm_feasible_candidate_for_all_four_configs() {
    let cluster = Cluster::passage_512(32_768);
    for cfg in 1..=4 {
        let w = Workload::paper_gpt_4p7t(cfg);
        let cands = enumerate_candidates(&w, &cluster);
        let paper = Mapping::new(Parallelism::paper(), w.moe);
        assert!(cands.contains(&paper), "config {cfg} misses the paper mapping");
        assert!(check_feasible(&w, &paper).is_ok(), "config {cfg} paper mapping infeasible");
    }
}

#[test]
fn planner_ranks_only_feasible_mappings() {
    // Config 1 (coarse experts, heaviest per-rank expert state at small
    // tp) is the config whose space still has HBM-infeasible points.
    let out = plan(&PlanRequest::paper(ClusterKey::Passage512, 1, &PerfKnobs::default()), 4);
    assert!(out.pruned > 0, "expected some HBM pruning");
    for p in &out.ranked {
        assert!(p.memory.fits());
        assert!(check_feasible(&Workload::paper_gpt_4p7t(1), &p.mapping).is_ok());
    }
}

#[test]
fn planner_output_is_byte_identical_for_any_worker_count() {
    // The `lumos plan --jobs N` contract, asserted at the artifact level.
    let knobs = PerfKnobs::default();
    for key in [ClusterKey::Passage512, ClusterKey::Electrical144] {
        let req = PlanRequest::paper(key, 4, &knobs).with_top(10);
        let serial = ranked_table(&plan(&req, 1)).render();
        for jobs in [2, 4, 7] {
            assert_eq!(serial, ranked_table(&plan(&req, jobs)).render(), "jobs={jobs}");
        }
    }
}

#[test]
fn planner_never_loses_to_the_paper_mapping_on_passage() {
    let knobs = PerfKnobs::default();
    for cfg in 1..=4 {
        let out = plan(&PlanRequest::paper(ClusterKey::Passage512, cfg, &knobs).with_top(1), 4);
        let best = out.best().expect("nonempty plan");
        let paper = out.paper_baseline.as_ref().expect("baseline on passage");
        assert!(
            best.report.time_to_train_s <= paper.time_to_train_s,
            "config {cfg}: planner {} > paper {}",
            best.report.time_to_train_s,
            paper.time_to_train_s
        );
    }
}
