//! Chaos-harness contract tests (ISSUE 10): fault plans are pure
//! functions of their seed; a supervised run with an empty plan is
//! bit-identical to the unsupervised driver; transient (rewind-free)
//! faults are absorbed without changing the training trajectory; and a
//! crash recovery terminates, lands inside the resilience model's
//! calibrated band, and reproduces byte-for-byte on rerun.

use lumos::chaos::{modeled_recovery, ChaosSpec, FaultPlan};
use lumos::runtime::{Artifact, Engine};
use lumos::trainer::{run_mapped, run_mapped_chaos, MiniMapping, RunOutcome};

fn chaotic(steps: usize, seed: u64, plan: Option<&FaultPlan>) -> RunOutcome {
    let engine = Engine::host();
    let art = Artifact::host_miniature();
    let m = MiniMapping { pp: 2, dp: 2, n_micro: 2 };
    run_mapped_chaos(&engine, &art, m, steps, seed, false, plan).expect("chaos run")
}

#[test]
fn same_seed_same_plan_and_digest() {
    let spec = ChaosSpec::parse("crash=1,drop=1,stall=1,corrupt=1,degrade=1").unwrap();
    for seed in [0u64, 7, 42, 0xDEAD_BEEF] {
        let a = FaultPlan::generate(&spec, seed, 2, 2, 2, 8, 2).unwrap();
        let b = FaultPlan::generate(&spec, seed, 2, 2, 2, 8, 2).unwrap();
        assert_eq!(a, b, "seed {seed}: plan not a pure function of its inputs");
        assert_eq!(a.digest(), b.digest());
    }
    let a = FaultPlan::generate(&spec, 7, 2, 2, 2, 8, 2).unwrap();
    let c = FaultPlan::generate(&spec, 8, 2, 2, 2, 8, 2).unwrap();
    assert_ne!(a.digest(), c.digest(), "digest blind to the seed");
    // Dropping one kind from the spec must not reshuffle the others'
    // coordinates (per-kind forked rng streams).
    let partial = ChaosSpec::parse("crash=1,stall=1").unwrap();
    let p = FaultPlan::generate(&partial, 7, 2, 2, 2, 8, 2).unwrap();
    for f in &p.faults {
        assert!(a.faults.contains(f), "removing kinds moved {f:?}");
    }
}

#[test]
fn supervised_empty_plan_run_is_bit_identical_to_plain() {
    let spec = ChaosSpec::parse("").unwrap();
    assert!(spec.is_empty());
    let plan = FaultPlan::generate(&spec, 7, 2, 2, 2, 3, 2).unwrap();
    assert!(plan.is_empty());

    let plain = {
        let engine = Engine::host();
        let art = Artifact::host_miniature();
        let m = MiniMapping { pp: 2, dp: 2, n_micro: 2 };
        run_mapped(&engine, &art, m, 3, 7, false).expect("plain run")
    };
    let supervised = chaotic(3, 7, Some(&plan));

    // The training trajectory is bit-identical: supervision only changes
    // the error path, never the data path or the bytes accounting.
    assert_eq!(plain.report.steps.len(), supervised.report.steps.len());
    for (a, b) in plain.report.steps.iter().zip(&supervised.report.steps) {
        assert_eq!(a.ce_loss.to_bits(), b.ce_loss.to_bits(), "step {}", a.step);
        assert_eq!(a.aux_loss.to_bits(), b.aux_loss.to_bits(), "step {}", a.step);
        assert_eq!(a.comm_bytes, b.comm_bytes, "step {}", a.step);
    }
    // Same recorded structure: identical (name, cat) span sequences per
    // rank (durations are wall-clock and may differ).
    assert_eq!(plain.recordings.len(), supervised.recordings.len());
    for (ra, rb) in plain.recordings.iter().zip(&supervised.recordings) {
        assert_eq!(ra.rank, rb.rank);
        let names = |r: &lumos::obs::Recording| {
            r.spans.iter().map(|s| (s.name.clone(), s.cat.clone())).collect::<Vec<_>>()
        };
        assert_eq!(names(ra), names(rb), "rank {}", ra.rank);
        assert!(rb.instants.iter().all(|(_, cat, _)| cat != "chaos"));
    }
    // A report is produced, and every chaos counter is zero.
    assert!(plain.chaos.is_none());
    let rep = supervised.chaos.expect("supervised run reports");
    assert_eq!(rep.plan_digest, plan.digest());
    assert!(rep.injected.is_empty());
    assert_eq!(rep.corruptions_detected, 0);
    assert_eq!(rep.repairs_served, 0);
    assert!(rep.dead_ranks.is_empty());
    assert_eq!((rep.rewinds, rep.steps_rolled_back, rep.degraded_steps), (0, 0, 0));
    assert_eq!(rep.committed_steps, 3);
    assert_eq!(rep.final_dp, 2);
}

#[test]
fn rewind_free_faults_are_absorbed_without_changing_the_trajectory() {
    let spec = ChaosSpec::parse("drop=1,corrupt=1,stall=1").unwrap();
    let plan = FaultPlan::generate(&spec, 21, 2, 2, 2, 4, 2).unwrap();
    assert_eq!(plan.faults.len(), 3);

    let clean = chaotic(4, 21, None);
    let faulted = chaotic(4, 21, Some(&plan));

    // No fail-stop fault => no rewind, no retirement, and the recovered
    // trajectory equals the fault-free one bit-for-bit.
    for (a, b) in clean.report.steps.iter().zip(&faulted.report.steps) {
        assert_eq!(a.ce_loss.to_bits(), b.ce_loss.to_bits(), "step {}", a.step);
    }
    let rep = faulted.chaos.expect("report");
    assert_eq!(rep.injected.get("drop"), Some(&1));
    assert_eq!(rep.injected.get("corrupt"), Some(&1));
    assert_eq!(rep.injected.get("stall"), Some(&1));
    assert_eq!(rep.corruptions_detected, 1, "checksum must catch the bit-flip");
    let modeled = modeled_recovery(&plan, 4);
    assert_eq!(rep.repairs_served, modeled.expected_repairs, "one repair per message fault");
    assert!(rep.dead_ranks.is_empty());
    assert_eq!((rep.rewinds, rep.steps_rolled_back, rep.degraded_steps), (0, 0, 0));
    assert_eq!(rep.committed_steps, 4);
    assert_eq!(rep.final_dp, 2);
    // The faults leave their trail in the flight recorder's chaos track.
    let marks: usize = faulted
        .recordings
        .iter()
        .map(|r| r.instants.iter().filter(|(_, cat, _)| cat == "chaos").count())
        .sum();
    assert!(marks >= 3, "expected one chaos instant per fired fault, got {marks}");
}

#[test]
fn crash_recovery_terminates_inside_the_modeled_band_and_reproduces() {
    let spec = ChaosSpec::parse("crash=1,drop=1").unwrap();
    let steps = 8;
    let plan = FaultPlan::generate(&spec, 5, 2, 2, 2, steps, 2).unwrap();

    let out = chaotic(steps, 5, Some(&plan));
    // Termination with a full log: the survivors rewound and committed
    // every step despite losing a DP replica.
    assert_eq!(out.report.steps.len(), steps);
    let rep = out.chaos.expect("report");
    assert_eq!(rep.dead_ranks.len(), 1, "exactly the planned crash victim dies");
    assert_eq!(rep.final_dp, 1);
    assert_eq!(rep.rewinds, 1);
    assert!(rep.steps_rolled_back >= 1 && rep.steps_rolled_back <= rep.ckpt_every);
    assert_eq!(rep.committed_steps, steps);
    assert!(rep.degraded_steps >= 1);

    // Executed degraded-step ratio sits inside the resilience model's
    // calibrated band (K / (2 * steps) per crash).
    let modeled = modeled_recovery(&plan, steps);
    let gap = (rep.degraded_ratio() - modeled.expected_degraded_ratio).abs();
    assert!(
        gap <= modeled.ratio_band,
        "executed ratio {} vs modeled {} exceeds band {}",
        rep.degraded_ratio(),
        modeled.expected_degraded_ratio,
        modeled.ratio_band
    );
    assert_eq!(rep.repairs_served, modeled.expected_repairs);

    // Reproducibility: the recovery report is a pure function of the
    // plan — a rerun (any thread interleaving) is byte-identical.
    let again = chaotic(steps, 5, Some(&plan)).chaos.expect("report");
    assert_eq!(rep, again);
    assert_eq!(
        rep.to_json().to_string_compact(),
        again.to_json().to_string_compact(),
        "recovery report must serialize byte-identically across reruns"
    );
    for (a, b) in out.report.steps.iter().zip(chaotic(steps, 5, Some(&plan)).report.steps.iter()) {
        assert_eq!(a.ce_loss.to_bits(), b.ce_loss.to_bits(), "step {}", a.step);
    }
}
