//! Property tests for the resilience substrate (ISSUE 4 satellite):
//! `hw::reliability` FIT-composition edge cases and the `util::rng`
//! determinism contract the Monte Carlo engine's `--jobs` invariance
//! rests on.

use lumos::hw::reliability::{FitRates, LinkReliability, Replaceable};
use lumos::prop_assert;
use lumos::resilience::{monte_carlo_trial, GoodputInputs, RepairModel};
use lumos::util::prop::check;
use lumos::util::rng::Rng;

fn random_link(g: &mut lumos::util::prop::Gen) -> LinkReliability {
    LinkReliability {
        name: "prop",
        lasers_per_link: g.usize(0, 8) as f64,
        laser_location: if g.bool() { Replaceable::FieldUnit } else { Replaceable::GpuTray },
        connectors_per_link: g.usize(0, 4) as f64,
        fits: FitRates {
            laser: g.f64(0.0, 1000.0),
            pic: g.f64(0.0, 100.0),
            electrical: g.f64(0.0, 100.0),
            connector: g.f64(0.0, 200.0),
        },
    }
}

#[test]
fn link_fit_is_monotone_in_every_fit_rate() {
    check("link_fit monotone", 256, |g| {
        let base = random_link(g);
        let delta = g.f64(0.0, 500.0);
        let field = g.usize(0, 3);
        let mut bumped = base.clone();
        match field {
            0 => bumped.fits.laser += delta,
            1 => bumped.fits.pic += delta,
            2 => bumped.fits.electrical += delta,
            _ => bumped.fits.connector += delta,
        }
        prop_assert!(
            bumped.link_fit() >= base.link_fit(),
            "field {field} bump by {delta} lowered link_fit: {} -> {}",
            base.link_fit(),
            bumped.link_fit()
        );
        // tray impact is monotone too (it sums a subset of the terms)
        prop_assert!(
            bumped.tray_impact_fit() >= base.tray_impact_fit(),
            "tray impact dropped on bump"
        );
        Ok(())
    });
}

#[test]
fn tray_impact_never_exceeds_link_fit() {
    check("tray <= link", 256, |g| {
        let l = random_link(g);
        prop_assert!(
            l.tray_impact_fit() <= l.link_fit() + 1e-12,
            "tray {} > link {}",
            l.tray_impact_fit(),
            l.link_fit()
        );
        // and the field/tray split partitions the total exactly
        let total = l.field_impact_fit() + l.tray_impact_fit();
        prop_assert!(
            (total - l.link_fit()).abs() <= 1e-9 * l.link_fit().max(1.0),
            "partition broken: {total} vs {}",
            l.link_fit()
        );
        Ok(())
    });
}

#[test]
fn zero_component_rates_are_degenerate_not_negative() {
    let zero = LinkReliability {
        name: "zero",
        lasers_per_link: 0.0,
        laser_location: Replaceable::GpuTray,
        connectors_per_link: 0.0,
        fits: FitRates { laser: 0.0, pic: 0.0, electrical: 0.0, connector: 0.0 },
    };
    assert_eq!(zero.link_fit(), 0.0);
    assert_eq!(zero.tray_impact_fit(), 0.0);
    assert_eq!(zero.field_impact_fit(), 0.0);
    // copper: lasers contribute nothing even at GpuTray placement
    let mut cu = LinkReliability::copper();
    cu.laser_location = Replaceable::GpuTray;
    assert_eq!(cu.tray_impact_fit(), cu.fits.electrical);
}

#[test]
fn forked_streams_are_independent_of_consumption_order() {
    // The resilience engine forks one stream per trial up front and runs
    // trials on a worker pool: a stream's output must not depend on when
    // (or in what order) the streams are consumed.
    check("fork order independence", 64, |g| {
        let seed = g.u64(u64::MAX);
        let n = g.usize(2, 24);
        let fork_all = |seed: u64| -> Vec<Rng> {
            let mut base = Rng::new(seed);
            (0..n).map(|t| base.fork(t as u64)).collect()
        };
        let drain = |rng: &Rng| -> Vec<u64> {
            let mut r = rng.clone();
            (0..16).map(|_| r.next_u64()).collect()
        };
        let streams = fork_all(seed);
        let forward: Vec<Vec<u64>> = streams.iter().map(drain).collect();
        let mut backward: Vec<Vec<u64>> = streams.iter().rev().map(drain).collect();
        backward.reverse();
        prop_assert!(forward == backward, "stream output depends on consumption order");
        // interleaved consumption does not couple streams either
        let mut interleaved: Vec<Vec<u64>> = streams.iter().map(|_| Vec::new()).collect();
        for round in 0..16 {
            for (i, s) in streams.iter().enumerate() {
                let mut r = s.clone();
                for _ in 0..round {
                    r.next_u64();
                }
                interleaved[i].push(r.next_u64());
            }
        }
        for (i, seq) in interleaved.iter().enumerate() {
            prop_assert!(*seq == forward[i][..seq.len()], "interleaving changed stream {i}");
        }
        // distinct trials see distinct streams
        prop_assert!(forward[0] != forward[1], "fork produced identical streams");
        Ok(())
    });
}

#[test]
fn monte_carlo_trials_are_order_independent() {
    // End-to-end form of the contract: per-trial effective TTTs are
    // identical whether trials run 0..n or n..0 — the property `--jobs N`
    // byte-identity reduces to.
    check("trial order independence", 16, |g| {
        let inp = GoodputInputs {
            healthy_step: 1.0,
            degraded_up_step: 1.0 + g.f64(0.0, 0.1),
            degraded_out_step: 1.0 + g.f64(0.0, 1.0),
            healthy_ttt: g.f64(1.0e4, 3.0e5),
            dp: g.usize(1, 512),
            lam_up_field_h: g.f64(0.0, 6.0),
            lam_out_field_h: g.f64(0.0, 0.5),
            lam_tray_h: g.f64(0.0, 0.1),
            repair: RepairModel::default(),
        };
        let seed = g.u64(u64::MAX);
        let n = 8usize;
        let mut base = Rng::new(seed);
        let streams: Vec<Rng> = (0..n).map(|t| base.fork(t as u64)).collect();
        let run = |i: usize| {
            let mut rng = streams[i].clone();
            monte_carlo_trial(&inp, &mut rng)
        };
        let forward: Vec<u64> = (0..n).map(|i| run(i).to_bits()).collect();
        let mut backward: Vec<u64> = (0..n).rev().map(|i| run(i).to_bits()).collect();
        backward.reverse();
        prop_assert!(forward == backward, "trial results depend on execution order");
        Ok(())
    });
}
