//! Observability contract tests (ISSUE 8): traces are deterministic —
//! byte-identical across rebuilds, thread-local engine pollution, and
//! worker counts — and structurally sound (Chrome trace-event schema,
//! per-track span nesting, per-stage tracks that partition the simulated
//! step to 1e-9 with the stage-0 track bit-equal to `lumos validate`'s
//! phase attribution). The `"metrics"` key of every `--json` artifact is
//! pinned here too: counters are monotonic, jobs-invariant, and agree
//! with the serial-equivalent SkeletonCache replay.

use lumos::collectives as coll;
use lumos::model::Workload;
use lumos::netsim::{schedule_chain_dag, simulate_dag_stats, Network};
use lumos::obs::{check_chrome_trace, resilience_trace, step_trace};
use lumos::parallel::Mapping;
use lumos::perf::PerfKnobs;
use lumos::planner::{outcome_json, plan_simulated, plan_with_cache, PlanRequest, SimSection};
use lumos::resilience::{
    assessments_json, default_mapping, paired_json, paper_pairs, pod_serviceability, sample_trace,
    DegradeSource, FabricReliability, RepairModel, ResilienceSpec,
};
use lumos::sweep::engine::{ClusterCache, ClusterKey};
use lumos::timeline::{replay_reuse, validate_mapping, validation_json, validation_metrics};
use lumos::topology::cluster::Cluster;
use lumos::util::rng::Rng;

/// The cheap golden point: Config 4 on one 512-GPU pod (TP16×PP1×DP32).
fn pod_point() -> (Workload, Cluster, Mapping, PerfKnobs) {
    let w = Workload::paper_gpt_4p7t(4);
    let cluster = Cluster::custom(512, 512, 32_000.0);
    let map = default_mapping(&w, &cluster).unwrap();
    (w, cluster, map, PerfKnobs::default())
}

#[test]
fn step_trace_tracks_partition_the_step_and_match_validate_bit_exactly() {
    let (w, c, m, k) = pod_point();
    let st = step_trace(&w, &c, &m, &k, false).unwrap();
    let v = validate_mapping(&w, &c, &m, &k).unwrap();

    // the traced simulation IS the validate simulation, bit for bit
    assert_eq!(st.report.step_time.to_bits(), v.simulated.step_time.to_bits());
    assert_eq!(st.report.nodes, v.simulated.nodes);
    assert_eq!(st.report.dep, v.simulated.dep);
    let (a, b) = (&st.report.phases, &v.simulated.phases);
    for (x, y) in [
        (a.compute, b.compute),
        (a.tp_comm, b.tp_comm),
        (a.ep_comm, b.ep_comm),
        (a.pp_comm, b.pp_comm),
        (a.dp_comm, b.dp_comm),
        (a.bubble, b.bubble),
    ] {
        assert_eq!(x.to_bits(), y.to_bits());
    }

    // every stage's span track partitions [0, step] to 1e-9 relative
    let step = st.report.step_time;
    assert!(step > 0.0);
    for (s, bd) in st.stages.iter().enumerate() {
        let sum = bd.total();
        assert!(
            ((sum - step) / step).abs() <= 1e-9,
            "stage {s} track sums to {sum}, step is {step}"
        );
    }

    // the emitted artifact is schema-valid and well-nested, with one span
    // track per pipeline stage and fabric counter samples alongside
    let check = check_chrome_trace(&st.trace.to_chrome_json()).unwrap();
    assert!(check.spans > 0, "{check:?}");
    assert!(check.counters > 0, "{check:?}");
    assert_eq!(check.tracks, st.stages.len());
}

#[test]
fn step_trace_bytes_survive_engine_pollution_and_fresh_threads() {
    let build = || {
        let (w, c, m, k) = pod_point();
        let st = step_trace(&w, &c, &m, &k, true).unwrap();
        st.trace.to_chrome_json().to_string_pretty()
    };
    let first = build();

    // pollute the shared thread-local dependency engine with unrelated
    // work: a rebuilt trace must not change by a byte
    let net = Network::sls(16, 1_600.0, 0.0);
    let nodes = schedule_chain_dag(&coll::ring_all_reduce_schedule(16, 1e6));
    let _ = simulate_dag_stats(&net, &nodes);
    assert_eq!(first, build());

    // a fresh thread (fresh thread-local state — the worker-pool proxy)
    // produces the same bytes
    let other = std::thread::spawn(build).join().unwrap();
    assert_eq!(first, other);
}

#[test]
fn planner_trace_and_metrics_are_jobs_invariant() {
    let knobs = PerfKnobs::default();
    let cache = ClusterCache::new();
    let key = ClusterKey::custom(512, 512, 32_000.0);
    let req = PlanRequest::paper(key.clone(), 4, &knobs);
    let cluster = cache.get(&key);
    let outcome = plan_with_cache(&req, 1, &cache);

    let sim1 = plan_simulated(&outcome, &req.workload, &cluster, &knobs, 1.05, 1);
    let sim8 = plan_simulated(&outcome, &req.workload, &cluster, &knobs, 1.05, 8);
    let j1 = outcome_json(&outcome, Some(&SimSection::from_plan(&sim1))).to_string_pretty();
    let j8 = outcome_json(&outcome, Some(&SimSection::from_plan(&sim8))).to_string_pretty();
    assert_eq!(j1, j8);
    assert!(j1.contains("\"metrics\""), "{j1}");
    assert!(j1.contains("\"sim_cache_hits\""), "{j1}");

    // the winner --trace would emit is the same plan either way, and its
    // trace is byte-identical
    let (w1, w8) = (&sim1.scored[0].plan.mapping, &sim8.scored[0].plan.mapping);
    assert_eq!(w1, w8);
    let t1 = step_trace(&req.workload, &cluster, w1, &knobs, false).unwrap();
    let t8 = step_trace(&req.workload, &cluster, w8, &knobs, false).unwrap();
    assert_eq!(
        t1.trace.to_chrome_json().to_string_pretty(),
        t8.trace.to_chrome_json().to_string_pretty()
    );
}

#[test]
fn dep_stats_reset_per_run_and_replay_reuse_is_monotonic() {
    // identical runs through the shared thread-local engine report
    // identical work counters: stats reset per run, monotonic within one
    let net = Network::sls(8, 800.0, 0.0);
    let nodes = schedule_chain_dag(&coll::ring_all_gather_schedule(8, 4e6));
    let (r1, s1) = simulate_dag_stats(&net, &nodes);
    let (r2, s2) = simulate_dag_stats(&net, &nodes);
    assert_eq!(r1.makespan.to_bits(), r2.makespan.to_bits());
    assert_eq!(s1, s2);
    assert!(s1.admitted_flows > 0 && s1.refills > 0, "{s1:?}");
    assert!(s1.refill_flows >= s1.refill_flows_max);

    // serial-equivalent SkeletonCache replay: one miss then hits for a
    // repeated mapping, hits non-decreasing over growing prefixes
    let (w, c, m, k) = pod_point();
    let maps = [&m, &m, &m];
    let (h1, m1) = replay_reuse(&w, &c, &maps[..1], &k);
    let (h2, m2) = replay_reuse(&w, &c, &maps[..2], &k);
    let (h3, m3) = replay_reuse(&w, &c, &maps, &k);
    assert_eq!((h1, m1), (0, 1));
    assert_eq!((h2, m2), (1, 1));
    assert_eq!((h3, m3), (2, 1));
    assert!(h1 <= h2 && h2 <= h3);
}

#[test]
fn validation_json_carries_monotonic_metrics() {
    let (w, c, m, k) = pod_point();
    let rows = vec![validate_mapping(&w, &c, &m, &k).unwrap()];
    let vj = validation_json(&c.spec.name, "Config 4", &rows);
    assert_eq!(vj.get("metrics").get("rows").as_f64(), Some(1.0));
    assert_eq!(
        vj.get("metrics").get("sim_admitted_flows").as_f64(),
        Some(rows[0].simulated.dep.admitted_flows as f64)
    );
    // counters only ever add: two rows dominate one row exactly
    let rows2 = vec![
        validate_mapping(&w, &c, &m, &k).unwrap(),
        validate_mapping(&w, &c, &m, &k).unwrap(),
    ];
    let m1 = validation_metrics(&rows);
    let m2 = validation_metrics(&rows2);
    assert_eq!(
        m2.counter("sim_admitted_flows"),
        2 * m1.counter("sim_admitted_flows")
    );
    assert_eq!(m2.counter("rows"), 2 * m1.counter("rows"));
}

#[test]
fn resilience_artifacts_are_jobs_invariant_and_carry_metrics() {
    let knobs = PerfKnobs::default();
    let cache = ClusterCache::new();
    let spec = ResilienceSpec {
        trials: 16,
        degrade: DegradeSource::Analytical,
        ..ResilienceSpec::default()
    };
    let serial = paper_pairs(&[4], &knobs, &spec, 1, &cache);
    let par = paper_pairs(&[4], &knobs, &spec, 8, &cache);
    let js = paired_json(&serial, 7, 16).to_string_pretty();
    assert_eq!(js, paired_json(&par, 7, 16).to_string_pretty());
    assert!(js.contains("\"metrics\""), "{js}");
    assert!(js.contains("\"degrade_source\""), "{js}");
    assert!(js.contains("\"mc_trials\""), "{js}");

    // the per-cluster artifact carries what its table header reports
    let pods = pod_serviceability(
        &knobs,
        &ResilienceSpec {
            trials: 0,
            degrade: DegradeSource::Analytical,
            ..ResilienceSpec::default()
        },
        1,
        &cache,
    );
    let aj = assessments_json(&pods, 7, 0);
    assert_eq!(aj.get("metrics").get("assessments").as_f64(), Some(3.0));
    assert_eq!(aj.get("degrade_source").as_str(), Some("analytical"));

    // seeded fault trace -> Chrome artifact: pure, byte-identical,
    // schema-valid (the `lumos resilience --trace` payload)
    let fab = FabricReliability::passage();
    let repair = RepairModel::default();
    let ev1 = sample_trace(&fab, &repair, 32_768, 48.0, Rng::new(7));
    let ev2 = sample_trace(&fab, &repair, 32_768, 48.0, Rng::new(7));
    let t1 = resilience_trace(&ev1, 1800.0, 48.0);
    let t2 = resilience_trace(&ev2, 1800.0, 48.0);
    assert_eq!(
        t1.to_chrome_json().to_string_pretty(),
        t2.to_chrome_json().to_string_pretty()
    );
    assert!(check_chrome_trace(&t1.to_chrome_json()).is_ok());
}
