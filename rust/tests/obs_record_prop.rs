//! Flight-recorder + trace-diff contract tests (ISSUE 9): recordings of
//! a real mapped run are schema-valid Chrome traces whose per-rank
//! tracks partition the executed step exactly; the trace diff is empty
//! on identical inputs, symmetric up to side swap, and renders
//! deterministically; and the executed trace diffs phase-by-phase
//! against the simulated step trace of the same planner mapping.

use lumos::obs::record::to_trace;
use lumos::obs::{check_chrome_trace, diff_json, diff_parsed, diff_table, diff_traces, step_trace};
use lumos::perf::PerfKnobs;
use lumos::resilience::default_mapping;
use lumos::runtime::{Artifact, Engine};
use lumos::topology::cluster::Cluster;
use lumos::trainer::{run_mapped, MiniMapping, RunOutcome};
use lumos::util::json::Json;

/// One small executed run: pp2 × dp2 × mb2 on four worker threads.
fn executed(steps: usize, seed: u64) -> RunOutcome {
    let engine = Engine::host();
    let art = Artifact::host_miniature();
    let m = MiniMapping { pp: 2, dp: 2, n_micro: 2 };
    run_mapped(&engine, &art, m, steps, seed, false).expect("mapped run")
}

#[test]
fn recorded_trace_is_schema_valid_and_tracks_partition_the_step() {
    let out = executed(2, 7);
    assert_eq!(out.recordings.len(), 4);

    // Partition by construction: every rank's spans tile [0, end_s]
    // with exact float contiguity — no gaps, no double attribution.
    for rec in &out.recordings {
        assert!(!rec.spans.is_empty());
        let mut cursor = 0.0;
        for s in &rec.spans {
            assert_eq!(s.start_s, cursor, "rank {} span {} leaves a gap", rec.rank, s.name);
            assert!(s.end_s >= s.start_s);
            cursor = s.end_s;
        }
        assert_eq!(cursor, rec.end_s);
    }

    // The merged artifact passes the same checker the CI smoke path runs.
    let doc = to_trace(&out.recordings).to_chrome_json();
    let check = check_chrome_trace(&doc).expect("recorded trace is schema-valid");
    assert_eq!(check.tracks, 4);
    assert!(check.spans > 0);
    assert!(check.instants >= 2 * 4, "one step instant per rank per step");
}

#[test]
fn recorded_shape_is_host_independent_across_runs() {
    // Two runs of the same mapped workload: wall-clock durations differ,
    // but normalize-at-capture makes the *structure* identical — same
    // tracks, span names, categories, and ordering.
    let a = to_trace(&executed(2, 7).recordings).to_chrome_json();
    let b = to_trace(&executed(2, 7).recordings).to_chrome_json();
    let (pa, pb) = (
        lumos::obs::parse_chrome_trace(&a).expect("parse"),
        lumos::obs::parse_chrome_trace(&b).expect("parse"),
    );
    assert_eq!(pa.spans.len(), pb.spans.len());
    for (x, y) in pa.spans.iter().zip(&pb.spans) {
        assert_eq!((&x.track, &x.name, &x.cat), (&y.track, &y.name, &y.cat));
    }
    // ... which is exactly what makes the pair diffable span-for-span.
    let d = diff_parsed(&pa, &pb);
    assert_eq!(d.matched, pa.spans.len());
    assert!(d.only_a.is_empty() && d.only_b.is_empty());
}

#[test]
fn self_diff_is_empty_and_diff_is_symmetric() {
    let doc_a = to_trace(&executed(2, 7).recordings).to_chrome_json();
    let doc_b = to_trace(&executed(3, 11).recordings).to_chrome_json();

    let self_d = diff_traces(&doc_a, &doc_a).expect("diff");
    assert!(self_d.is_empty());

    let ab = diff_traces(&doc_a, &doc_b).expect("diff");
    let ba = diff_traces(&doc_b, &doc_a).expect("diff");
    assert_eq!(ab.matched, ba.matched);
    assert_eq!(ab.only_a, ba.only_b);
    assert_eq!(ab.only_b, ba.only_a);
    for (key, p) in &ab.phases {
        let q = ba.phases[key];
        assert_eq!(p.a_s.to_bits(), q.b_s.to_bits());
        assert_eq!(p.b_s.to_bits(), q.a_s.to_bits());
    }
    // The 3-step side has one extra step's spans; they surface as
    // unmatched occurrences of already-known (track, name) pairs.
    assert!(ab.only_a.is_empty());
    assert!(!ab.only_b.is_empty());
}

#[test]
fn diff_renders_are_deterministic_functions_of_their_inputs() {
    let doc_a = to_trace(&executed(2, 7).recordings).to_chrome_json();
    let doc_b = to_trace(&executed(2, 11).recordings).to_chrome_json();
    let d1 = diff_traces(&doc_a, &doc_b).expect("diff");
    let d2 = diff_traces(&doc_a, &doc_b).expect("diff");
    assert_eq!(diff_table(&d1, "A", "B"), diff_table(&d2, "A", "B"));
    assert_eq!(
        diff_json(&d1, "A", "B").to_string_pretty(),
        diff_json(&d2, "A", "B").to_string_pretty()
    );
    // Round-trip through the serialized artifact (what `lumos trace
    // --diff` reads back from disk) changes nothing.
    let ser = Json::parse(&doc_a.to_string_pretty()).expect("round-trip");
    let d3 = diff_traces(&ser, &doc_b).expect("diff");
    assert_eq!(diff_table(&d1, "A", "B"), diff_table(&d3, "A", "B"));
}

#[test]
fn executed_trace_diffs_against_the_simulated_step_phase_by_phase() {
    // The simulated side: one step of the same six-phase vocabulary on
    // a cheap pod point. The executed side: the mapped miniature.
    let w = lumos::model::Workload::paper_gpt_4p7t(4);
    let cluster = Cluster::custom(512, 512, 32_000.0);
    let map = default_mapping(&w, &cluster).expect("mapping");
    let knobs = PerfKnobs::default();
    let sim = step_trace(&w, &cluster, &map, &knobs, false).expect("simulate");
    let exec = to_trace(&executed(2, 7).recordings).to_chrome_json();

    let d = diff_traces(&sim.trace.to_chrome_json(), &exec).expect("diff");
    // Track names differ by design (stage vs rank), so nothing aligns
    // span-for-span — the comparison lives in the phase shares.
    assert_eq!(d.matched, 0);
    assert!(d.total_a() > 0.0);
    assert!(d.total_b() > 0.0);
    let compute = d.phases["compute"];
    assert!(compute.a_s > 0.0, "simulated step has compute time");
    assert!(compute.b_s > 0.0, "executed step has compute time");
    // Both sides speak the same six-phase vocabulary: nothing lands in
    // the "other" bucket on either side (the executed step instants are
    // instants, not spans).
    let other = d.phases["other"];
    assert_eq!(other.a_s, 0.0);
    assert_eq!(other.b_s, 0.0);
}
