//! Goldens for the `timeline` discrete-event step simulator (ISSUE 3
//! acceptance): the analytical-vs-simulated gap stays within a pinned
//! tolerance on the paper's configurations, the per-phase breakdown
//! partitions the simulated step exactly, and the cross-check preserves
//! the paper's cluster ranking.

use lumos::model::MoeConfig;
use lumos::model::Workload;
use lumos::parallel::{Mapping, Parallelism};
use lumos::perf::PerfKnobs;
use lumos::timeline::{
    estimate_nodes, simulate_step, validate_mapping, Validation, DEEP_REGION_MIN_NODES,
};
use lumos::topology::cluster::Cluster;

fn validate(cluster: &Cluster, cfg: usize) -> Validation {
    let w = Workload::paper_gpt_4p7t(cfg);
    let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(cfg));
    validate_mapping(&w, cluster, &m, &PerfKnobs::default()).unwrap()
}

#[test]
fn passage512_paper_mapping_gap_within_15_percent() {
    // Acceptance: `lumos validate` on Passage-512 reports an
    // analytical-vs-simulated step gap ≤ 15% for the paper mapping.
    // (Measured ≈ +6.4%: the DAG exposes the 25% EP-overlap credit and the
    // 90% DP-overlap credit the closed form grants; everything else lines
    // up within a percent.)
    let v = validate(&Cluster::passage_512(32_768), 4);
    let gap = v.gap();
    assert!(gap.abs() <= 0.15, "gap {gap}");
    // The simulator grants no overlap for free, so it must be the slower
    // (conservative) side of the comparison.
    assert!(gap > 0.0, "gap {gap}");
}

#[test]
fn all_paper_configs_stay_within_tolerance_on_passage() {
    let cluster = Cluster::passage_512(32_768);
    for cfg in 1..=4 {
        let v = validate(&cluster, cfg);
        let gap = v.gap();
        assert!(gap > 0.0 && gap <= 0.15, "config {cfg}: gap {gap}");
    }
}

#[test]
fn phase_breakdown_partitions_the_simulated_step() {
    // Acceptance: the per-phase breakdown sums to the simulated total.
    for cluster in [Cluster::passage_512(32_768), Cluster::electrical_144(32_256)] {
        let v = validate(&cluster, 4);
        let total = v.simulated.phases.total();
        let rel = (total - v.simulated.step_time).abs() / v.simulated.step_time;
        assert!(rel <= 1e-9, "{}: {} vs {}", cluster.spec.name, total, v.simulated.step_time);
    }
}

#[test]
fn simulation_preserves_the_section6_cluster_ranking() {
    // The whole point of the cross-check: the simulated step times must
    // tell the same story as the analytical ones — Passage fastest, the
    // same-radix electrical slower, the 144-pod alternative slowest.
    let p = validate(&Cluster::passage_512(32_768), 4);
    let e512 = validate(&Cluster::electrical_512(32_768), 4);
    let e144 = validate(&Cluster::electrical_144(32_256), 4);
    assert!(p.simulated.step_time < e512.simulated.step_time);
    assert!(e512.simulated.step_time < e144.simulated.step_time);
    // and the simulated headline speedup stays in the paper's ballpark
    let speedup = e144.simulated.time_to_train_s / p.simulated.time_to_train_s;
    assert!(speedup > 2.3, "simulated speedup {speedup}");
}

#[test]
fn electrical144_gap_exposes_the_ep_overlap_credit() {
    // On the 144-pod alternative the EP all-to-all dominates the step, so
    // the closed form's 25% EP-overlap assumption is load-bearing there:
    // the simulator (which hides nothing) runs measurably slower. This is
    // a *finding*, pinned here: the gap is real but bounded.
    let v = validate(&Cluster::electrical_144(32_256), 4);
    let gap = v.gap();
    assert!(gap > 0.05 && gap < 0.35, "gap {gap}");
    // EP is the biggest exposed communication phase there
    let p = &v.simulated.phases;
    assert!(p.ep_comm > p.tp_comm && p.ep_comm > p.dp_comm, "{p:?}");
}

#[test]
fn dp_overlap_emerges_from_the_dag() {
    // The analytical model exposes only (1-dp_overlap) = 10% of the DP
    // sync; the DAG exposes what the dependency structure forces: stage
    // 0's sync cannot start before the last backward, so its full duration
    // is exposed — and it should be close to the analytical dp_comm.
    let v = validate(&Cluster::passage_512(32_768), 4);
    let sim_dp = v.simulated.phases.dp_comm;
    let ana_dp = v.analytical.breakdown.dp_comm_per_step;
    assert!((sim_dp - ana_dp).abs() / ana_dp < 0.05, "sim {sim_dp} vs ana {ana_dp}");
}

#[test]
fn previously_rejected_deep_pp_mapping_now_simulates_end_to_end() {
    // ISSUE-5 acceptance: a mapping from the region MAX_DAG_NODES=300k used
    // to reject (deep-PP × fine-microbatch — exactly where the planner
    // wants simulation) now lowers, simulates, and validates end-to-end on
    // the incremental dependency engine. TP8×PP64×DP64 lowers to ~305k
    // nodes, just past the old cap.
    let w = Workload::paper_gpt_4p7t(4);
    let cluster = Cluster::passage_512(32_768);
    let m = Mapping::try_with_microbatch(
        Parallelism { tp: 8, pp: 64, dp: 64 },
        MoeConfig::paper_config(4),
        1,
    )
    .unwrap();
    assert!(
        estimate_nodes(&m, m.n_micro(&w)) > DEEP_REGION_MIN_NODES,
        "mapping no longer in the previously-rejected region"
    );
    let v = validate_mapping(&w, &cluster, &m, &PerfKnobs::default()).unwrap();
    // the estimate (305k) is the rejection gate; the realized lowering is
    // ~229k nodes (mirror-measured) — still far past anything the old
    // full-recompute engine could execute
    assert!(v.simulated.nodes > 100_000, "{}", v.simulated.nodes);
    assert!(v.simulated.step_time > 0.0 && v.simulated.step_time.is_finite());
    // the per-phase breakdown still partitions the simulated step exactly
    let p = &v.simulated.phases;
    let rel = (p.total() - v.simulated.step_time).abs() / v.simulated.step_time;
    assert!(rel <= 1e-9, "phases sum {} vs step {}", p.total(), v.simulated.step_time);
    // deep pipelines at n_micro == pp carry a large bubble; the simulator
    // must agree with the 1F1B structure, not collapse it
    assert!(p.bubble > 0.0);
    // the analytical model stays the faster (optimistic) side here too
    assert!(v.gap() > 0.0, "gap {}", v.gap());
}

#[test]
fn microbatch_grain_shifts_bubble_in_the_simulator_too() {
    // Coarser microbatches => fewer slots => bigger bubble fraction, in
    // the simulator just as in the closed form.
    let w = Workload::paper_gpt_4p7t(1);
    let cluster = Cluster::passage_512(32_768);
    let knobs = PerfKnobs::default();
    let m1 = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(1));
    let m4 = m1.clone().with_microbatch(4);
    let r1 = simulate_step(&w, &cluster, &m1, &knobs).unwrap();
    let r4 = simulate_step(&w, &cluster, &m4, &knobs).unwrap();
    let frac = |r: &lumos::timeline::TimelineReport| r.phases.bubble / r.step_time;
    assert!(frac(&r4) > frac(&r1), "{} vs {}", frac(&r4), frac(&r1));
}
