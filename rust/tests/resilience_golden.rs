//! Goldens for the `resilience` subsystem (ISSUE 4 acceptance): the
//! paper's serviceability argument (§II.C.3, §III.d) asserted as numbers.
//!
//! - Laser placement: with default FIT rates and repair times,
//!   integrated-laser CPO loses strictly more effective time-to-train than
//!   external-laser Passage at equal bandwidth on the 512-GPU pod, and at
//!   the full 32k-GPU scale the integrated-laser design diverges outright
//!   (tray MTBF ≈ 13 min — the arXiv 2603.21313 "wrong problem" regime).
//! - The headline survives the accounting: the availability-adjusted
//!   Passage-vs-Electrical-144 speedup is **wider** than the healthy one
//!   on every Table IV config and stays ≥ 2.5× where the paper's 2.7×
//!   headline lives (Config 4 adjusts to ≈ 3.2×).
//! - Determinism: `lumos resilience` output is byte-identical across
//!   `--jobs` and reproducible from `--seed`.

use lumos::model::Workload;
use lumos::perf::PerfKnobs;
use lumos::resilience::{
    self, assess, default_mapping, paper_pairs, pod_serviceability, speedup_table,
    DegradeSource, FabricReliability, ResilienceSpec,
};
use lumos::sweep::engine::{ClusterCache, ClusterKey};

/// Closed form only, analytical degraded ratios: the mode the pinned
/// headline numbers below were calibrated on (the measured-ratio mode is
/// pinned separately by `measured_degraded_ratios_*`).
fn closed_form_spec() -> ResilienceSpec {
    ResilienceSpec { trials: 0, degrade: DegradeSource::Analytical, ..ResilienceSpec::default() }
}

#[test]
fn integrated_laser_loses_strictly_more_ttt_on_the_pod() {
    // Equal bandwidth, equal performance — only the laser placement
    // differs. The external-laser design loses ~1 day of effective TTT to
    // failures on one 512-GPU pod; the integrated-laser design loses >10x
    // that because every laser failure is a tray event.
    let rows =
        pod_serviceability(&PerfKnobs::default(), &closed_form_spec(), 1, &ClusterCache::new());
    let ext = &rows[0]; // Passage (external laser)
    let cpo = &rows[1]; // CPO (integrated laser)
    assert_eq!(ext.steps.healthy_ttt.to_bits(), cpo.steps.healthy_ttt.to_bits());
    assert!(ext.expected.effective_ttt.is_finite());
    assert!(cpo.expected.effective_ttt.is_finite());
    assert!(cpo.expected.effective_ttt > ext.expected.effective_ttt);
    assert!(
        cpo.ttt_lost_s() > 5.0 * ext.ttt_lost_s(),
        "cpo lost {} vs external lost {}",
        cpo.ttt_lost_s(),
        ext.ttt_lost_s()
    );
    // the mechanism: tray events, not total failure count
    assert!(cpo.tray_per_year > 50.0 * ext.tray_per_year);
}

#[test]
fn integrated_laser_cpo_diverges_at_cluster_scale() {
    // 32k GPUs x 72 links x integrated lasers: a tray event every ~13
    // minutes destroys work faster than the job creates it.
    let cache = ClusterCache::new();
    let cluster = cache.get(&ClusterKey::Passage512);
    let w = Workload::paper_gpt_4p7t(4);
    let map = default_mapping(&w, &cluster).unwrap();
    let a = assess(
        &w,
        &cluster,
        &map,
        &PerfKnobs::default(),
        &FabricReliability::cpo_integrated(),
        &closed_form_spec(),
        1,
    );
    assert!(a.expected.effective_ttt.is_infinite(), "{}", a.expected.effective_ttt);
    assert_eq!(a.expected.availability, 0.0);
    assert!(a.expected.tray_mtbf_h < 0.5, "{}", a.expected.tray_mtbf_h);
}

#[test]
fn adjusted_speedup_holds_the_headline_on_all_configs() {
    let rows = paper_pairs(
        &[1, 2, 3, 4],
        &PerfKnobs::default(),
        &closed_form_spec(),
        2,
        &ClusterCache::new(),
    );
    assert_eq!(rows.len(), 4);
    for r in &rows {
        // failures cost both fabrics time...
        assert!(r.passage.expected.effective_ttt > r.passage.steps.healthy_ttt);
        assert!(r.electrical.expected.effective_ttt > r.electrical.steps.healthy_ttt);
        // ...but the electrical alternative pays more on every config: its
        // spilled EP all-to-all rides exactly the links that degrade, so
        // the availability accounting *widens* the Passage advantage.
        assert!(
            r.adjusted_speedup() > r.healthy_speedup(),
            "config {}: adjusted {} vs healthy {}",
            r.config,
            r.adjusted_speedup(),
            r.healthy_speedup()
        );
    }
    // the Config 4 headline: 2.71x healthy, >= 2.5x (≈3.2x) adjusted
    let c4 = &rows[3];
    assert!((c4.healthy_speedup() - 2.7).abs() < 0.15, "{}", c4.healthy_speedup());
    assert!(c4.adjusted_speedup() >= 2.5, "{}", c4.adjusted_speedup());
    assert!(c4.adjusted_speedup() > 3.0, "{}", c4.adjusted_speedup());
}

#[test]
fn monte_carlo_agrees_with_the_closed_form() {
    // MC and the closed form consume identical GoodputInputs, so the
    // agreement property is independent of the degrade source; analytical
    // keeps the test cheap.
    let spec = ResilienceSpec {
        trials: 48,
        degrade: DegradeSource::Analytical,
        ..ResilienceSpec::default()
    };
    let rows = paper_pairs(&[4], &PerfKnobs::default(), &spec, 2, &ClusterCache::new());
    for a in [&rows[0].passage, &rows[0].electrical] {
        let cf = a.expected.effective_ttt;
        assert!(
            (a.mc_mean_ttt - cf).abs() / cf < 0.15,
            "{}: mc {} vs closed form {}",
            a.cluster,
            a.mc_mean_ttt,
            cf
        );
        assert!(a.mc_min_ttt <= a.mc_mean_ttt && a.mc_mean_ttt <= a.mc_max_ttt);
        // failures make every trial slower than the healthy run
        assert!(a.mc_min_ttt > a.steps.healthy_ttt);
    }
}

#[test]
fn output_is_byte_identical_across_jobs_and_reproducible_from_seed() {
    let knobs = PerfKnobs::default();
    let cache = ClusterCache::new();
    let spec = ResilienceSpec {
        seed: 7,
        trials: 64,
        degrade: DegradeSource::Analytical,
        ..ResilienceSpec::default()
    };
    let serial = paper_pairs(&[4], &knobs, &spec, 1, &cache);
    let parallel = paper_pairs(&[4], &knobs, &spec, 4, &cache);
    assert_eq!(speedup_table(&serial).render(), speedup_table(&parallel).render());
    assert_eq!(
        resilience::paired_json(&serial, 7, 64).to_string_pretty(),
        resilience::paired_json(&parallel, 7, 64).to_string_pretty()
    );
    // same seed reproduces bit-exactly; a different seed does not
    let again = paper_pairs(&[4], &knobs, &spec, 2, &cache);
    assert_eq!(
        serial[0].passage.mc_mean_ttt.to_bits(),
        again[0].passage.mc_mean_ttt.to_bits()
    );
    let other_spec = ResilienceSpec { seed: 8, ..spec.clone() };
    let other = paper_pairs(&[4], &knobs, &other_spec, 2, &cache);
    assert_ne!(
        serial[0].passage.mc_mean_ttt.to_bits(),
        other[0].passage.mc_mean_ttt.to_bits()
    );
}

#[test]
fn measured_degraded_ratios_track_the_simulated_blast_radius() {
    // The ISSUE-5 loop closure: `lumos resilience` now prices degradation
    // from ratios *measured* on the timeline step DAG (one victim GPU's
    // links removed) instead of the analytical slowest-member bound. Pin
    // the structure of that refinement on Config 4:
    //
    // - the healthy anchors are bit-identical between the two modes (the
    //   measured mode changes only degradation pricing);
    // - the blast-radius asymmetry survives measurement: the electrical
    //   144-pod fabric's measured scale-out ratio exceeds Passage's
    //   (spilled EP rides exactly the degraded NICs);
    // - a single measured victim prices *below* the analytical
    //   whole-cluster slowest-member bound on the electrical fabric (the
    //   closed form is the conservative side), and the resulting
    //   closed-form effective-TTT drift between the two modes stays
    //   bounded;
    // - failures still cost both fabrics time, and the adjusted Config-4
    //   speedup stays comfortably above the region where the paper's 2.7×
    //   headline would be threatened.
    let knobs = PerfKnobs::default();
    let cache = ClusterCache::new();
    let sim_spec = ResilienceSpec { trials: 0, ..ResilienceSpec::default() };
    assert_eq!(sim_spec.degrade, DegradeSource::Simulated);
    let sim = &paper_pairs(&[4], &knobs, &sim_spec, 1, &cache)[0];
    let ana = &paper_pairs(&[4], &knobs, &closed_form_spec(), 1, &cache)[0];

    for (s, a) in [(&sim.passage, &ana.passage), (&sim.electrical, &ana.electrical)] {
        assert_eq!(s.degrade_source, DegradeSource::Simulated);
        assert_eq!(a.degrade_source, DegradeSource::Analytical);
        assert_eq!(s.steps.healthy_ttt.to_bits(), a.steps.healthy_ttt.to_bits());
        assert_eq!(s.steps.healthy_step.to_bits(), a.steps.healthy_step.to_bits());
        // failures only cost time, in both modes
        assert!(s.expected.effective_ttt > s.steps.healthy_ttt);
        assert!(s.steps.up_ratio() >= 1.0 && s.steps.out_ratio() >= 1.0);
        // drift between the modes is a refinement, not a regime change
        let drift = s.expected.effective_ttt / a.expected.effective_ttt;
        assert!((0.7..=1.3).contains(&drift), "{}: drift {drift}", s.cluster);
    }
    // blast-radius asymmetry survives measurement
    assert!(
        sim.electrical.steps.out_ratio() > sim.passage.steps.out_ratio(),
        "electrical {} vs passage {}",
        sim.electrical.steps.out_ratio(),
        sim.passage.steps.out_ratio()
    );
    assert!(sim.electrical.steps.out_ratio() > 1.05, "{}", sim.electrical.steps.out_ratio());
    // a single measured victim stays in the neighborhood of the analytical
    // whole-cluster slowest-member bound on the electrical fabric: the
    // victim's halved NICs stretch the same EP tail the closed form
    // doubles, but the sim never charges more than the barrier structure
    // forces
    assert!(
        sim.electrical.steps.out_ratio() <= ana.electrical.steps.out_ratio() * 1.3,
        "measured {} vs analytical {}",
        sim.electrical.steps.out_ratio(),
        ana.electrical.steps.out_ratio()
    );
    // the headline is not threatened by the refinement
    assert!(sim.healthy_speedup() > 2.5, "{}", sim.healthy_speedup());
    assert!(sim.adjusted_speedup() >= 2.4, "{}", sim.adjusted_speedup());
}

#[test]
fn degraded_simulation_confirms_the_analytical_blast_radius() {
    // The timeline cross-check of the degrade path: a failed scale-out
    // pluggable re-simulated on the step DAG hurts the 144-pod electrical
    // fabric (spilled EP) far more than Passage (in-pod EP).
    use lumos::model::MoeConfig;
    use lumos::parallel::{Mapping, Parallelism};
    use lumos::resilience::degrade::{simulate_degraded_step, DegradedMode};
    use lumos::timeline::simulate_step;
    use lumos::topology::cluster::Cluster;

    let knobs = PerfKnobs::default();
    let w = Workload::paper_gpt_4p7t(4);
    let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(4));
    let ratio = |cluster: &Cluster| {
        let healthy = simulate_step(&w, cluster, &m, &knobs).unwrap().step_time;
        let degraded =
            simulate_degraded_step(&w, cluster, &m, &knobs, DegradedMode::ScaleOutLink, 0.5)
                .unwrap()
                .step_time;
        degraded / healthy
    };
    let psg = ratio(&Cluster::passage_512(32_768));
    let alt = ratio(&Cluster::electrical_144(32_256));
    assert!(alt > psg, "electrical degraded ratio {alt} vs passage {psg}");
    assert!(alt > 1.1, "{alt}");
    assert!(psg < 1.1, "{psg}");
}
