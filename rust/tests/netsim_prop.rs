//! Property tests for the netsim invariants (ISSUE 1): max-min allocation
//! never exceeds link capacity, bytes are conserved against line rates,
//! per-flow completion times stay inside the batch makespan, and the
//! incremental fast path agrees with the full-recompute reference to
//! ≤ 1e-9 relative. ISSUE 3 adds the dependency-driven engine's contract:
//! on chain-dependency (full-barrier) schedules it reproduces the
//! bulk-synchronous `replay_schedule` oracle to ≤ 1e-9 relative. ISSUE 5
//! adds the incremental dependency engine's contract: on randomized DAGs
//! (random topologies × dependency shapes) `simulate_dag` agrees with the
//! full-recompute `simulate_dag_reference` oracle to ≤ 1e-9 relative, per
//! node. ISSUE 7 swaps the heap engine in behind `simulate_dag` and adds
//! the triangle contract — heap == scan == reference on random DAGs, plus
//! a rate-churn stress aimed at the heap's lazy invalidation. Uses the
//! in-tree `util::prop` framework (seeded, shrinking; override with
//! `LUMOS_PROP_SEED`).

use lumos::collectives as coll;
use lumos::netsim::{
    fair_rates, replay_schedule, replay_schedule_dependent, schedule_chain_dag, simulate,
    simulate_dag, simulate_dag_reference, simulate_dag_scan, simulate_reference, DagNode,
    DagSimulator, Flow, Network,
};
use lumos::prop_assert;
use lumos::util::prop::{check, Gen};

/// Random single-pod or two-level network with strictly positive capacities.
fn random_net(g: &mut Gen) -> Network {
    let pods = g.usize(1, 4);
    let pod = g.usize(2, 6);
    let n = pods * pod;
    let up = *g.choose(&[800.0, 1_600.0, 14_400.0]);
    let out = *g.choose(&[100.0, 400.0, 1_600.0]);
    let oversub = *g.choose(&[1.0, 1.5, 2.0, 4.0]);
    let lat = *g.choose(&[0.0, 5e-6]);
    if pods == 1 {
        Network::sls(n, up, lat)
    } else {
        Network::cluster(n, pod, up, out, oversub, lat)
    }
}

/// Random flow batch; mixes zero-byte flows in to exercise the skip path.
fn random_flows(g: &mut Gen, net: &Network) -> Vec<Flow> {
    let n = net.n_nodes;
    let count = g.usize(1, 48);
    (0..count)
        .map(|_| {
            let src = g.usize(0, n - 1);
            let mut dst = g.usize(0, n - 1);
            if dst == src {
                dst = (dst + 1) % n;
            }
            let bytes = if g.bool() { g.f64(1e3, 1e8) } else { 0.0 };
            net.flow(src, dst, bytes)
        })
        .collect()
}

#[test]
fn prop_max_min_rates_respect_link_capacity() {
    check("rates never exceed link capacity", 96, |g| {
        let net = random_net(g);
        let flows = random_flows(g, &net);
        let rates = fair_rates(&net, &flows);
        let mut load = vec![0.0f64; net.links.len()];
        for (f, r) in flows.iter().zip(&rates) {
            for &l in &f.path {
                load[l] += r;
            }
        }
        for (l, link) in net.links.iter().enumerate() {
            prop_assert!(
                load[l] <= link.capacity * (1.0 + 1e-9),
                "link {l} oversubscribed: {} > {}",
                load[l],
                link.capacity
            );
        }
        // work conservation at the flow level: positive demand never starves
        for (i, (f, r)) in flows.iter().zip(&rates).enumerate() {
            if f.bytes > 0.0 {
                prop_assert!(*r > 0.0, "flow {i} starved");
            } else {
                prop_assert!(*r == 0.0, "zero-byte flow {i} got rate {r}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bytes_conserved_against_line_rates() {
    check("no link or flow beats line rate", 96, |g| {
        let net = random_net(g);
        let flows = random_flows(g, &net);
        let r = simulate(&net, &flows);
        let lat = net.base_latency;
        let transfer = r.makespan - lat;
        prop_assert!(transfer >= -1e-12, "negative transfer window {transfer}");
        // per-link conservation: a link cannot move more bytes than
        // capacity × busy-time
        let mut through = vec![0.0f64; net.links.len()];
        for f in &flows {
            for &l in &f.path {
                through[l] += f.bytes;
            }
        }
        for (l, link) in net.links.iter().enumerate() {
            prop_assert!(
                through[l] <= link.capacity * transfer * (1.0 + 1e-9) + 1e-6,
                "link {l} moved {} B in {transfer}s at cap {}",
                through[l],
                link.capacity
            );
        }
        // per-flow: completion inside the makespan, and no flow beats the
        // narrowest link on its path
        for (i, f) in flows.iter().enumerate() {
            let t = r.flow_times[i];
            prop_assert!(
                t <= r.makespan + 1e-12,
                "flow {i} finishes at {t} after makespan {}",
                r.makespan
            );
            let min_cap = f.path.iter().map(|&l| net.links[l].capacity).fold(f64::INFINITY, f64::min);
            prop_assert!(
                t + 1e-12 >= lat + f.bytes / min_cap,
                "flow {i} beat line rate: {t} < {}",
                lat + f.bytes / min_cap
            );
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_matches_reference() {
    check("incremental vs full recompute <= 1e-9 relative", 64, |g| {
        let net = random_net(g);
        let flows = random_flows(g, &net);
        let fast = simulate(&net, &flows);
        let slow = simulate_reference(&net, &flows);
        let tol = |x: f64| 1e-9 * x.abs().max(1e-12);
        prop_assert!(
            (fast.makespan - slow.makespan).abs() <= tol(slow.makespan),
            "makespan {} vs {}",
            fast.makespan,
            slow.makespan
        );
        for (i, (a, b)) in fast.flow_times.iter().zip(&slow.flow_times).enumerate() {
            prop_assert!((a - b).abs() <= tol(*b), "flow {i}: {a} vs {b}");
        }
        Ok(())
    });
}

/// Random multi-step schedule over `net` (mixes collective shapes with
/// arbitrary op soups, including zero-byte and repeated (src, dst) pairs).
fn random_schedule(g: &mut Gen, net: &Network) -> coll::CommSchedule {
    let n = net.n_nodes;
    match g.usize(0, 2) {
        0 => coll::ring_all_reduce_schedule(n, g.f64(1e5, 1e8)),
        1 => coll::pairwise_a2a_schedule(n, g.f64(1e5, 1e8)),
        _ => {
            let steps = g.usize(1, 6);
            let mut ops = Vec::new();
            for step in 0..steps {
                for _ in 0..g.usize(1, 12) {
                    let src = g.usize(0, n - 1);
                    let dst = g.usize(0, n - 1);
                    let bytes = if g.bool() { g.f64(1e3, 1e7) } else { 0.0 };
                    ops.push(coll::CommOp { step, src, dst, bytes });
                }
            }
            coll::CommSchedule::new("random", n, ops)
        }
    }
}

#[test]
fn prop_chain_dag_reproduces_bulk_synchronous_replay() {
    // The degenerate chain case of the dependency engine (full barriers
    // between steps) must agree with replay_schedule — the acceptance
    // contract of the dependency-driven netsim.
    check("chain-dep dag == bulk replay <= 1e-9 relative", 48, |g| {
        let net = random_net(g);
        let sched = random_schedule(g, &net);
        let bulk = replay_schedule(&net, &sched);
        let dag = simulate_dag(&net, &schedule_chain_dag(&sched));
        let tol = |x: f64| 1e-9 * x.abs().max(1e-30);
        prop_assert!(
            (dag.makespan - bulk.makespan).abs() <= tol(bulk.makespan),
            "makespan {} vs {}",
            dag.makespan,
            bulk.makespan
        );
        // nodes are emitted in the same step-major order replay reports
        prop_assert!(
            dag.finish.len() == bulk.flow_times.len(),
            "{} vs {} flows",
            dag.finish.len(),
            bulk.flow_times.len()
        );
        for (i, (a, b)) in dag.finish.iter().zip(&bulk.flow_times).enumerate() {
            prop_assert!((a - b).abs() <= tol(*b), "flow {i}: {a} vs {b}");
        }
        Ok(())
    });
}

/// Random task DAG over `net`: a mix of delays and flows (zero-byte and
/// self-loop flows included) with one of three dependency shapes —
/// layered barriers (the timeline's block structure), chain-heavy
/// (pipeline-like), or sparse random fan-in. Nodes are emitted in
/// topological order by construction.
fn random_dag(g: &mut Gen, net: &Network) -> Vec<DagNode> {
    let n_nodes = g.usize(1, 60);
    let shape = g.usize(0, 2);
    let mut nodes: Vec<DagNode> = Vec::with_capacity(n_nodes);
    let mut layer_start = 0usize;
    for i in 0..n_nodes {
        let deps: Vec<usize> = if i == 0 {
            Vec::new()
        } else {
            match shape {
                // layered barriers: depend on every node of the previous
                // layer block (layers of ~4)
                0 => {
                    if i % 4 == 0 {
                        layer_start = i.saturating_sub(4);
                    }
                    (layer_start..i.min(layer_start + 4)).collect()
                }
                // chain-heavy: previous node, sometimes one extra
                1 => {
                    let mut d = vec![i - 1];
                    if g.bool() && i >= 2 {
                        d.push(g.usize(0, i - 2));
                    }
                    d.sort_unstable();
                    d.dedup();
                    d
                }
                // sparse random fan-in (possibly a root)
                _ => {
                    let k = g.usize(0, 3.min(i));
                    let mut d: Vec<usize> =
                        (0..k).map(|_| g.usize(0, i - 1)).collect();
                    d.sort_unstable();
                    d.dedup();
                    d
                }
            }
        };
        let node = if g.usize(0, 3) == 0 {
            // delays, including zero-duration
            let dur = if g.bool() { g.f64(1e-6, 5e-3) } else { 0.0 };
            DagNode::delay(dur, deps)
        } else {
            let n = net.n_nodes;
            let src = g.usize(0, n - 1);
            // self-loops exercise the zero-work flow path
            let dst = if g.usize(0, 7) == 0 { src } else { g.usize(0, n - 1) };
            let bytes = if g.bool() { g.f64(1e3, 1e8) } else { 0.0 };
            DagNode::flow(src, dst, bytes, deps)
        };
        nodes.push(node);
    }
    nodes
}

#[test]
fn prop_incremental_dag_matches_reference() {
    // The ISSUE-5 acceptance contract: the component-incremental dependency
    // engine agrees with the full-recompute oracle to ≤ 1e-9 relative on
    // randomized (topology × dependency-shape) DAGs, node by node.
    check("incremental simulate_dag == simulate_dag_reference", 64, |g| {
        let net = random_net(g);
        let dag = random_dag(g, &net);
        let fast = simulate_dag(&net, &dag);
        let slow = simulate_dag_reference(&net, &dag);
        let tol = |x: f64| 1e-9 * x.abs().max(1e-12);
        prop_assert!(
            (fast.makespan - slow.makespan).abs() <= tol(slow.makespan),
            "makespan {} vs {}",
            fast.makespan,
            slow.makespan
        );
        prop_assert!(
            fast.finish.len() == slow.finish.len(),
            "{} vs {} nodes",
            fast.finish.len(),
            slow.finish.len()
        );
        for (i, (a, b)) in fast.finish.iter().zip(&slow.finish).enumerate() {
            prop_assert!((a - b).abs() <= tol(*b), "node {i}: {a} vs {b}");
        }
        Ok(())
    });
}

/// Rate-churn DAG: long-lived flows out of one hot rank, admitted in
/// waves behind a delay chain. Every admission and completion changes the
/// rate of *every* active flow (they all share the hot rank's uplink), so
/// the lazy heap's timed completion entries go stale constantly — the
/// worst case for generation-based invalidation and the settlement hook.
fn rate_churn_dag(g: &mut Gen, net: &Network) -> Vec<DagNode> {
    let hot = g.usize(0, net.n_nodes - 1);
    let n_waves = g.usize(3, 8);
    let mut nodes: Vec<DagNode> = Vec::new();
    let mut prev_delay: Option<usize> = None;
    for _ in 0..n_waves {
        let deps = prev_delay.map(|d| vec![d]).unwrap_or_default();
        nodes.push(DagNode::delay(g.f64(1e-6, 1e-4), deps));
        let delay_idx = nodes.len() - 1;
        for _ in 0..g.usize(1, 6) {
            let dst = g.usize(0, net.n_nodes - 1);
            nodes.push(DagNode::flow(hot, dst, g.f64(1e5, 1e8), vec![delay_idx]));
        }
        prev_delay = Some(delay_idx);
    }
    nodes
}

#[test]
fn prop_heap_dag_matches_scan_and_reference() {
    // The ISSUE-7 acceptance contract for the lazy completion-time heap:
    // on randomized DAGs it agrees with both the PR 5 incremental dt-scan
    // engine and the full-recompute oracle to ≤ 1e-9 relative, node by
    // node. (`simulate_dag` *is* the heap engine; the scan survives as
    // `simulate_dag_scan` exactly so this triangle stays checkable.)
    check("heap == scan == reference on random DAGs", 64, |g| {
        let net = random_net(g);
        let dag = random_dag(g, &net);
        let heap = simulate_dag(&net, &dag);
        let scan = simulate_dag_scan(&net, &dag);
        let slow = simulate_dag_reference(&net, &dag);
        let tol = |x: f64| 1e-9 * x.abs().max(1e-12);
        prop_assert!(
            (heap.makespan - slow.makespan).abs() <= tol(slow.makespan),
            "heap vs ref makespan {} vs {}",
            heap.makespan,
            slow.makespan
        );
        prop_assert!(
            (heap.makespan - scan.makespan).abs() <= tol(scan.makespan),
            "heap vs scan makespan {} vs {}",
            heap.makespan,
            scan.makespan
        );
        for (i, (a, b)) in heap.finish.iter().zip(&slow.finish).enumerate() {
            prop_assert!((a - b).abs() <= tol(*b), "heap vs ref node {i}: {a} vs {b}");
        }
        for (i, (a, b)) in heap.finish.iter().zip(&scan.finish).enumerate() {
            prop_assert!((a - b).abs() <= tol(*b), "heap vs scan node {i}: {a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn prop_heap_dag_survives_rate_churn() {
    // Stress the heap's lazy invalidation specifically: shared-bottleneck
    // DAGs where every event re-rates every active flow, so almost every
    // heap entry is stale by the time it surfaces.
    check("heap == reference under rate churn", 48, |g| {
        let net = random_net(g);
        let dag = rate_churn_dag(g, &net);
        let heap = simulate_dag(&net, &dag);
        let slow = simulate_dag_reference(&net, &dag);
        let tol = |x: f64| 1e-9 * x.abs().max(1e-12);
        prop_assert!(
            (heap.makespan - slow.makespan).abs() <= tol(slow.makespan),
            "makespan {} vs {}",
            heap.makespan,
            slow.makespan
        );
        for (i, (a, b)) in heap.finish.iter().zip(&slow.finish).enumerate() {
            prop_assert!((a - b).abs() <= tol(*b), "node {i}: {a} vs {b}");
        }
        Ok(())
    });
}

#[test]
fn prop_dag_simulator_reuse_matches_fresh_runs() {
    // The reusable-buffer contract: one DagSimulator fed a sequence of
    // unrelated (net, dag) pairs must report exactly what a *brand-new*
    // simulator reports for each pair — no state may leak across runs.
    // (Deliberately not compared against `simulate_dag`, whose
    // thread-local simulator has its own call history.)
    check("DagSimulator reuse is stateless", 24, |g| {
        let mut sim = DagSimulator::new();
        for _ in 0..3 {
            let net = random_net(g);
            let dag = random_dag(g, &net);
            let reused = sim.simulate(&net, &dag);
            let fresh = DagSimulator::new().simulate(&net, &dag);
            prop_assert!(
                reused.makespan.to_bits() == fresh.makespan.to_bits(),
                "makespan {} vs {}",
                reused.makespan,
                fresh.makespan
            );
            prop_assert!(reused.finish == fresh.finish, "finish vectors differ");
        }
        Ok(())
    });
}

#[test]
fn prop_rank_local_replay_is_sane_and_not_below_line_rate() {
    // Rank-local admission may finish earlier OR later than bulk barriers
    // (an early flow can contend with the previous step's stragglers), but
    // it can never beat the per-rank physics: every rank still moves its
    // total bytes through its own uplink serially.
    check("dependent replay respects per-rank line rate", 48, |g| {
        let net = random_net(g);
        let sched = random_schedule(g, &net);
        let dep = replay_schedule_dependent(&net, &sched);
        prop_assert!(
            dep.makespan.is_finite() && dep.makespan >= 0.0,
            "bad makespan {}",
            dep.makespan
        );
        // per-src serialization bound: sum of a rank's bytes / its uplink
        let mut per_src = vec![0.0f64; net.n_nodes];
        for op in sched.ops.iter().filter(|o| o.src != o.dst) {
            per_src[op.src] += op.bytes;
        }
        for (src, &bytes) in per_src.iter().enumerate() {
            if bytes <= 0.0 {
                continue;
            }
            let up_cap = net
                .links
                .iter()
                .find(|l| l.name == format!("gpu{src}-up"))
                .map(|l| l.capacity)
                .unwrap();
            let bound = bytes / up_cap;
            prop_assert!(
                dep.makespan + 1e-12 >= bound,
                "makespan {} beats src {src} line-rate bound {bound}",
                dep.makespan
            );
        }
        Ok(())
    });
}

#[test]
fn prop_replayed_schedules_keep_flow_times_in_makespan() {
    check("replay flow times bounded by makespan", 32, |g| {
        let n = g.usize(3, 12);
        let bytes = g.f64(1e5, 1e8);
        let net = Network::sls(n, 1_600.0, 1e-6);
        let sched = if g.bool() {
            coll::ring_all_reduce_schedule(n, bytes)
        } else {
            coll::pairwise_a2a_schedule(n, bytes)
        };
        let r = replay_schedule(&net, &sched);
        prop_assert!(!r.flow_times.is_empty(), "empty replay");
        for (i, &t) in r.flow_times.iter().enumerate() {
            prop_assert!(t > 0.0, "flow {i} nonpositive time {t}");
            prop_assert!(t <= r.makespan + 1e-12, "flow {i}: {t} > {}", r.makespan);
        }
        Ok(())
    });
}
