//! Golden tests: every headline number in the paper, asserted end-to-end
//! through the public API. Tolerances reflect "the shape must hold" (who
//! wins, by roughly what factor) rather than bit-exact replication of the
//! authors' proprietary tool.

use lumos::hw;
use lumos::model::Workload;
use lumos::perf::{evaluate_paper_config, paper_clusters, EpPlacement, PerfKnobs};
use lumos::planner::{plan, PlanRequest};
use lumos::sweep::engine::ClusterKey;

// ---------------------------------------------------------------- Fig 10/11

fn ratios(knobs: &PerfKnobs) -> Vec<(f64, f64, f64)> {
    let (passage, alt512, alt144) = paper_clusters();
    let base = evaluate_paper_config(&passage, 1, knobs).step_time;
    (1..=4)
        .map(|i| {
            let p = evaluate_paper_config(&passage, i, knobs).step_time;
            let a5 = evaluate_paper_config(&alt512, i, knobs).step_time;
            let a1 = evaluate_paper_config(&alt144, i, knobs).step_time;
            (p / base, a5 / p, a1 / p)
        })
        .collect()
}

#[test]
fn fig10_same_radix_alternative_1p3_to_1p4x() {
    let r = ratios(&PerfKnobs::default());
    // Paper: 1.4x for Configs 1-2, 1.3x for Configs 3-4.
    assert!((r[0].1 - 1.4).abs() < 0.08, "C1 {}", r[0].1);
    assert!((r[1].1 - 1.4).abs() < 0.08, "C2 {}", r[1].1);
    assert!((r[2].1 - 1.3).abs() < 0.10, "C3 {}", r[2].1);
    assert!((r[3].1 - 1.3).abs() < 0.10, "C4 {}", r[3].1);
}

#[test]
fn fig10_passage_scales_flat_across_configs() {
    let r = ratios(&PerfKnobs::default());
    // Paper: Config 4 costs only 1.02x Config 1 on Passage.
    for (i, row) in r.iter().enumerate() {
        assert!((row.0 - 1.0).abs() < 0.04, "config {}: {}", i + 1, row.0);
    }
}

#[test]
fn fig11_system_radix_1p6_to_2p7x() {
    let r = ratios(&PerfKnobs::default());
    assert!((r[0].2 - 1.6).abs() < 0.1, "C1 {}", r[0].2);
    assert!((r[3].2 - 2.7).abs() < 0.15, "C4 {}", r[3].2);
    // monotone degradation with finer experts
    assert!(r[0].2 < r[1].2 && r[1].2 < r[2].2 && r[2].2 < r[3].2);
}

#[test]
fn fig11_driven_by_ep_spilling_to_scaleout() {
    let (passage, _, alt144) = paper_clusters();
    let knobs = PerfKnobs::default();
    let p = evaluate_paper_config(&passage, 4, &knobs);
    let a = evaluate_paper_config(&alt144, 4, &knobs);
    assert_eq!(p.breakdown.ep_placement, EpPlacement::ScaleUp);
    assert_eq!(a.breakdown.ep_placement, EpPlacement::Hierarchical);
    // §VI: the alternative becomes increasingly bottlenecked by expert
    // communication.
    assert!(a.comm_fraction > p.comm_fraction + 0.2);
}

// ------------------------------------------------------------ Table I / III

#[test]
fn table3_energy_rows() {
    assert!((hw::lpo_dr8().total_pj_per_bit() - 13.0).abs() < 1e-9);
    assert!((hw::cpo_2p5d().total_pj_per_bit() - 12.0).abs() < 1e-9);
    assert!((hw::passage_interposer().total_pj_per_bit() - 4.3).abs() < 1e-9);
}

#[test]
fn fig7_power_2p8x() {
    let (rows, advantage) = hw::fig7_comparison(32_000.0);
    assert_eq!(rows.len(), 4);
    assert!((advantage - 2.8).abs() < 0.1, "{advantage}");
}

#[test]
fn fig8_area_ratios() {
    let r_lpo = hw::additional_area_ratio(&hw::lpo_dr8(), &hw::passage_interposer(), 400.0);
    let r_cpo = hw::additional_area_ratio(&hw::cpo_2p5d(), &hw::passage_interposer(), 400.0);
    assert!((r_lpo - 123.0).abs() < 8.0, "{r_lpo}");
    assert!((r_cpo - 6.6).abs() < 0.4, "{r_cpo}");
}

#[test]
fn abstract_8x_scaleup_claim() {
    // "8X increase in scale-up capability": 512 pods × 32T vs 144 × 14.4T
    // in aggregate pod bandwidth: (512*32)/(144*14.4) = 7.9x.
    let x: f64 = (512.0 * 32_000.0) / (144.0 * 14_400.0);
    assert!((x - 8.0).abs() < 0.15, "{x}");
}

#[test]
fn headline_2p7x_time_to_train() {
    let (passage, _, alt144) = paper_clusters();
    let knobs = PerfKnobs::default();
    let p = evaluate_paper_config(&passage, 4, &knobs);
    let a = evaluate_paper_config(&alt144, 4, &knobs);
    let speedup = a.time_to_train_s / p.time_to_train_s;
    assert!((speedup - 2.7).abs() < 0.15, "{speedup}");
    // Training 13T tokens takes days, not minutes or years.
    let days = p.time_to_train_s / 86_400.0;
    assert!(days > 1.0 && days < 60.0, "{days} days");
}

// ----------------------------------------------------------------- planner

#[test]
fn planner_found_speedup_meets_the_2p7x_headline() {
    // The paper's 2.7x is measured with the mapping *fixed* at
    // TP16×PP8×DP256 on both systems. Freeing the mapping on each fabric
    // must not erode the headline: the planner-found Passage advantage
    // stays >= 2.7x (and in fact widens — the 8x larger scale-up domain
    // benefits more from mapping freedom, which is the paper's
    // "new opportunities for multi-dimensional parallelism" claim).
    let knobs = PerfKnobs::default();
    let p = plan(&PlanRequest::paper(ClusterKey::Passage512, 4, &knobs).with_top(1), 4);
    let e = plan(&PlanRequest::paper(ClusterKey::Electrical144, 4, &knobs).with_top(1), 4);
    let planned = e.best().unwrap().report.time_to_train_s
        / p.best().unwrap().report.time_to_train_s;
    assert!(planned >= 2.7, "planner-found speedup {planned}");
    let fixed = e.paper_baseline.as_ref().unwrap().time_to_train_s
        / p.paper_baseline.as_ref().unwrap().time_to_train_s;
    assert!(planned > fixed, "mapping freedom should widen the gap: {planned} vs {fixed}");
}

#[test]
fn planner_top_mapping_beats_the_paper_mapping_on_passage() {
    let knobs = PerfKnobs::default();
    let out = plan(&PlanRequest::paper(ClusterKey::Passage512, 4, &knobs).with_top(1), 4);
    let best = out.best().unwrap();
    let paper = out.paper_baseline.as_ref().unwrap();
    assert!(
        best.report.time_to_train_s <= paper.time_to_train_s,
        "planner {} vs paper {}",
        best.report.time_to_train_s,
        paper.time_to_train_s
    );
}

// ------------------------------------------------------------ workload facts

#[test]
fn model_is_4p7t_params() {
    for i in 1..=4 {
        let p = Workload::paper_gpt_4p7t(i).total_params();
        assert!((p / 1e12 - 4.7).abs() < 0.1, "config {i}: {p}");
    }
}

#[test]
fn ep_group_exactly_fills_passage_pod() {
    use lumos::model::MoeConfig;
    use lumos::parallel::{Mapping, Parallelism};
    for i in 1..=4 {
        let m = Mapping::new(Parallelism::paper(), MoeConfig::paper_config(i));
        assert_eq!(m.ep_span_gpus(), 512);
    }
}
