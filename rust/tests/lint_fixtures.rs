//! Fixture matrix for `lumos lint`: for every rule a firing snippet, a
//! suppressed snippet, and a clean snippet — plus the self-check that the
//! crate's own sources lint clean (the CI gate in miniature) and the
//! `--jobs` independence contract on the report.

use std::path::PathBuf;

use lumos::analysis::{lint_paths, lint_source, report_json, rules, LintReport};

/// Lint one snippet with all rules; return (rule ids fired, suppressed count).
fn run(src: &str) -> (Vec<&'static str>, usize) {
    let (findings, suppressed) = lint_source("fixture.rs", src, &[]);
    (findings.into_iter().map(|f| f.rule).collect(), suppressed)
}

/// One fixture row: the snippet must fire exactly `rule`; the suppressed
/// variant (directive on the line above the first line) must be silent;
/// the clean variant must produce nothing.
struct Fixture {
    rule: &'static str,
    firing: &'static str,
    suppressed: &'static str,
    clean: &'static str,
}

const FIXTURES: &[Fixture] = &[
    Fixture {
        rule: "hash-iter",
        firing: "use std::collections::HashMap;\nfn f() -> HashMap<u8, u8> { todo!() }\n",
        suppressed: "// lumos: allow(hash-iter) -- keys are re-sorted before output\n\
                     use std::collections::HashMap;\n",
        clean: "use std::collections::BTreeMap;\nfn f() -> BTreeMap<u8, u8> { todo!() }\n",
    },
    Fixture {
        rule: "wallclock",
        firing: "fn f() { let t0 = std::time::Instant::now(); drop(t0); }\n",
        suppressed: "// lumos: allow(wallclock) -- bench harness measures real time\n\
                     fn f() { let t0 = std::time::Instant::now(); drop(t0); }\n",
        clean: "fn f(clock: f64) -> f64 { clock + 1.0 }\n",
    },
    Fixture {
        rule: "entropy",
        firing: "fn f() -> f64 { rand::random() }\n",
        suppressed: "// lumos: allow(entropy) -- seeding the master stream itself\n\
                     fn f() -> u64 { OsRng.next_u64() }\n",
        clean: "fn f(rng: &mut Rng) -> f64 { rng.next_f64() }\n",
    },
    Fixture {
        rule: "float-reduce",
        firing: "fn f(rx: Receiver<f64>) -> f64 {\n\
                 let mut acc = 0.0;\n\
                 while let Ok(v) = rx.recv() { acc += v; }\n\
                 acc }\n",
        suppressed: "fn f(rx: Receiver<f64>) -> f64 {\n\
                     let mut acc = 0.0;\n\
                     // lumos: allow(float-reduce) -- integral counters only\n\
                     while let Ok(v) = rx.recv() { acc += v; }\n\
                     acc }\n",
        clean: "fn f(parts: &[f64]) -> f64 { parts.iter().sum() }\n",
    },
    Fixture {
        rule: "panic-path",
        firing: "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n",
        suppressed: "// lumos: allow(panic-path) -- x is Some by construction\n\
                     fn f(x: Option<u8>) -> u8 { x.unwrap() }\n",
        clean: "fn f(x: Option<u8>) -> u8 { x.unwrap_or(0) }\n",
    },
    Fixture {
        rule: "unsafe-safety",
        firing: "fn f() { unsafe { go() } }\n",
        suppressed: "// lumos: allow(unsafe-safety) -- documented at the impl block\n\
                     fn f() { unsafe { go() } }\n",
        clean: "// SAFETY: the layout is pinned by the artifact manifest\n\
                fn f() { unsafe { go() } }\n",
    },
    Fixture {
        rule: "lint-directive",
        firing: "// lumos: allow(panic-path)\nfn f() {}\n",
        suppressed: "// lumos: allow(lint-directive) -- exercising the meta-rule\n\
                     fn f() {} // lumos: allow(panic-path)\n",
        clean: "// lumos: allow(panic-path) -- covers the line below\nfn f() { x.unwrap(); }\n",
    },
];

#[test]
fn every_rule_has_a_fixture() {
    let covered: Vec<&str> = FIXTURES.iter().map(|f| f.rule).collect();
    for r in rules::RULES {
        assert!(covered.contains(&r.id), "no fixture row for rule {}", r.id);
    }
    assert_eq!(covered.len(), rules::RULES.len());
}

#[test]
fn firing_fixtures_fire_their_rule() {
    for fx in FIXTURES {
        let (fired, suppressed) = run(fx.firing);
        assert!(
            fired.contains(&fx.rule),
            "{}: firing snippet produced {:?}",
            fx.rule,
            fired
        );
        assert_eq!(suppressed, 0, "{}: firing snippet should not suppress", fx.rule);
    }
}

#[test]
fn suppressed_fixtures_are_silent_and_counted() {
    for fx in FIXTURES {
        let (fired, suppressed) = run(fx.suppressed);
        assert!(
            !fired.contains(&fx.rule),
            "{}: suppressed snippet still fired {:?}",
            fx.rule,
            fired
        );
        assert!(suppressed >= 1, "{}: suppression not counted", fx.rule);
    }
}

#[test]
fn clean_fixtures_are_clean() {
    for fx in FIXTURES {
        let (fired, _) = run(fx.clean);
        assert!(
            !fired.contains(&fx.rule),
            "{}: clean snippet fired {:?}",
            fx.rule,
            fired
        );
    }
}

#[test]
fn rule_filter_scopes_the_scan() {
    // one snippet with two violations; --rule keeps only the asked-for one
    let src = "use std::collections::HashMap;\nfn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
    let (all, _) = lint_source("fixture.rs", src, &[]);
    assert_eq!(all.len(), 2);
    let (only, _) = lint_source("fixture.rs", src, &["panic-path".to_string()]);
    assert_eq!(only.len(), 1);
    assert_eq!(only[0].rule, "panic-path");
}

#[test]
fn test_regions_are_exempt() {
    let src = "#[cfg(test)]\nmod tests {\n\
               use std::collections::HashMap;\n\
               #[test] fn t() { let _ = std::time::Instant::now(); x.unwrap(); }\n}\n";
    let (fired, _) = run(src);
    assert!(fired.is_empty(), "test region fired {fired:?}");
}

#[test]
fn directive_variants_are_diagnosed() {
    // missing reason
    let (fired, _) = run("// lumos: allow(wallclock)\nfn f() {}\n");
    assert_eq!(fired, vec!["lint-directive"]);
    // unknown rule id
    let (fired, _) = run("// lumos: allow(no-such-rule) -- why\nfn f() {}\n");
    assert_eq!(fired, vec!["lint-directive"]);
    // dangling: no code after the directive
    let (fired, _) = run("fn f() {}\n// lumos: allow(panic-path) -- dangles\n");
    assert_eq!(fired, vec!["lint-directive"]);
}

fn crate_src() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
}

/// The CI gate in miniature: the crate's own sources must lint clean, and
/// the suppression inventory must be substantial (the sweep really ran).
#[test]
fn crate_sources_lint_clean() {
    let report = lint_paths(&[crate_src()], &[], 2).expect("lint run");
    let shown: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(report.findings.is_empty(), "crate not lint-clean:\n{}", shown.join("\n"));
    assert!(report.files_scanned >= 50, "only {} files scanned", report.files_scanned);
    assert!(report.suppressed >= 20, "only {} suppressions", report.suppressed);
}

/// Byte-identical reports across worker counts — the same contract the CI
/// gate diffs via `--json`.
#[test]
fn report_is_jobs_independent() {
    let one = lint_paths(&[crate_src()], &[], 1).expect("jobs=1");
    let four = lint_paths(&[crate_src()], &[], 4).expect("jobs=4");
    assert_eq!(one.findings, four.findings);
    assert_eq!(one.files_scanned, four.files_scanned);
    assert_eq!(one.suppressed, four.suppressed);
    assert_eq!(
        report_json(&one).to_string_pretty(),
        report_json(&four).to_string_pretty()
    );
}

/// A seeded violation on disk is caught end-to-end through lint_paths —
/// the same path the CI canary exercises through the binary.
#[test]
fn seeded_violation_on_disk_is_caught() {
    let dir = std::env::temp_dir().join(format!("lumos_lint_canary_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("canary.rs");
    std::fs::write(&path, "use std::collections::HashMap;\npub fn f() {}\n")
        .expect("write canary");
    let report = lint_paths(&[dir.clone()], &[], 1).expect("lint canary");
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(report.findings.len(), 1);
    assert_eq!(report.findings[0].rule, "hash-iter");
    assert_eq!(report.findings[0].line, 1);
    assert!(report.findings[0].file.ends_with("canary.rs"));
}

/// JSON report shape is stable: the keys the CI gate parses exist.
#[test]
fn json_report_has_gate_keys() {
    let report = LintReport {
        findings: lint_source("a.rs", "fn f() { q.unwrap(); }\n", &[]).0,
        files_scanned: 1,
        suppressed: 0,
    };
    let j = report_json(&report);
    assert_eq!(j.get("files_scanned").as_usize(), Some(1));
    assert_eq!(j.get("suppressed").as_usize(), Some(0));
    let arr = j.get("findings").as_arr().expect("findings array");
    assert_eq!(arr.len(), 1);
    assert_eq!(arr[0].get("rule").as_str(), Some("panic-path"));
    assert_eq!(arr[0].get("line").as_usize(), Some(1));
}
