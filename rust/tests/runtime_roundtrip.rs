//! Integration: the full AOT bridge — python-lowered HLO text executed from
//! rust via PJRT, validated against the manifest and against training-
//! dynamics expectations (loss decreases on a fixed batch).
//!
//! Requires `make artifacts` (artifacts/tiny). Tests that need it are
//! skipped (with a note) when artifacts are absent so `cargo test` still
//! passes in a fresh checkout.

use lumos::runtime::{artifacts_root, Artifact, Engine, Tensor};
use lumos::util::rng::Rng;

fn tiny() -> Option<Artifact> {
    let root = artifacts_root().ok()?;
    Artifact::load(root.join("tiny")).ok()
}

macro_rules! require_artifacts {
    () => {
        match tiny() {
            Some(a) => a,
            None => {
                eprintln!("SKIP: artifacts/tiny missing; run `make artifacts`");
                return;
            }
        }
    };
}

fn random_tokens(art: &Artifact, rng: &mut Rng) -> Tensor {
    let batch = art.cfg_usize("batch").unwrap();
    let seq = art.cfg_usize("seq_len").unwrap();
    let vocab = art.cfg_usize("vocab").unwrap();
    let data: Vec<i32> = (0..batch * (seq + 1))
        .map(|_| rng.below(vocab as u64) as i32)
        .collect();
    Tensor::I32(data, vec![batch, seq + 1])
}

#[test]
fn manifest_loads_and_is_consistent() {
    let art = require_artifacts!();
    assert!(art.n_params > 0);
    assert_eq!(art.param_names.len(), art.n_params);
    for name in ["init", "train_step", "grad_step", "apply_update", "forward"] {
        let e = art.entry(name).unwrap();
        assert!(!e.inputs.is_empty() || name == "init");
        assert!(!e.outputs.is_empty());
    }
    let ts = art.entry("train_step").unwrap();
    assert_eq!(ts.inputs.len(), art.state_len() + 1);
    assert_eq!(ts.outputs.len(), art.state_len() + 2);
}

#[test]
fn init_produces_manifest_shaped_state() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let init = engine.load(&art, "init").unwrap();
    let state = init.execute(&[Tensor::scalar_u32(0)]).unwrap();
    assert_eq!(state.len(), art.state_len());
    // step counter is the last element and starts at 0
    assert_eq!(state.last().unwrap().scalar_value().unwrap(), 0.0);
    // params are not all zero
    let norm: f64 = state[0]
        .as_f32()
        .unwrap()
        .iter()
        .map(|&x| (x as f64).abs())
        .sum();
    assert!(norm > 0.0);
}

#[test]
fn init_is_deterministic_in_seed() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let init = engine.load(&art, "init").unwrap();
    let a = init.execute(&[Tensor::scalar_u32(7)]).unwrap();
    let b = init.execute(&[Tensor::scalar_u32(7)]).unwrap();
    let c = init.execute(&[Tensor::scalar_u32(8)]).unwrap();
    assert_eq!(a[0], b[0]);
    assert_ne!(a[0], c[0]);
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let init = engine.load(&art, "init").unwrap();
    let train = engine.load(&art, "train_step").unwrap();

    let mut state = init.execute(&[Tensor::scalar_u32(0)]).unwrap();
    let mut rng = Rng::new(42);
    let tokens = random_tokens(&art, &mut rng);

    let mut first = None;
    let mut last = 0.0;
    for _ in 0..12 {
        let mut inputs = state.clone();
        inputs.push(tokens.clone());
        let mut out = train.execute(&inputs).unwrap();
        let aux = out.pop().unwrap().scalar_value().unwrap();
        let ce = out.pop().unwrap().scalar_value().unwrap();
        assert!(ce.is_finite() && aux.is_finite());
        state = out;
        if first.is_none() {
            first = Some(ce);
        }
        last = ce;
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.9,
        "loss did not decrease: first={first} last={last}"
    );
    // step counter advanced
    assert_eq!(state.last().unwrap().scalar_value().unwrap(), 12.0);
}

#[test]
fn grad_then_apply_matches_train_step() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let init = engine.load(&art, "init").unwrap();
    let train = engine.load(&art, "train_step").unwrap();
    let grad = engine.load(&art, "grad_step").unwrap();
    let apply = engine.load(&art, "apply_update").unwrap();

    let state = init.execute(&[Tensor::scalar_u32(1)]).unwrap();
    let mut rng = Rng::new(7);
    let tokens = random_tokens(&art, &mut rng);
    let p = art.n_params;

    // Path A: fused train_step.
    let mut inputs = state.clone();
    inputs.push(tokens.clone());
    let mut out_a = train.execute(&inputs).unwrap();
    let _aux = out_a.pop().unwrap();
    let ce_a = out_a.pop().unwrap().scalar_value().unwrap();

    // Path B: grad_step then apply_update (the DP-coordinator path).
    let mut grad_inputs: Vec<Tensor> = state[..p].to_vec();
    grad_inputs.push(tokens);
    let mut gout = grad.execute(&grad_inputs).unwrap();
    let _aux_b = gout.pop().unwrap();
    let ce_b = gout.pop().unwrap().scalar_value().unwrap();
    assert!((ce_a - ce_b).abs() < 1e-5 * ce_a.abs().max(1.0));

    let mut apply_inputs = state.clone();
    apply_inputs.extend(gout);
    let out_b = apply.execute(&apply_inputs).unwrap();

    // First parameter tensor must match between the two paths.
    let pa = out_a[0].as_f32().unwrap();
    let pb = out_b[0].as_f32().unwrap();
    let worst = pa
        .iter()
        .zip(pb)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(worst < 1e-5, "param divergence {worst}");
}

#[test]
fn forward_emits_logits() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let init = engine.load(&art, "init").unwrap();
    let fwd = engine.load(&art, "forward").unwrap();

    let state = init.execute(&[Tensor::scalar_u32(0)]).unwrap();
    let batch = art.cfg_usize("batch").unwrap();
    let seq = art.cfg_usize("seq_len").unwrap();
    let vocab = art.cfg_usize("vocab").unwrap();
    let mut rng = Rng::new(3);
    let tokens = Tensor::I32(
        (0..batch * seq).map(|_| rng.below(vocab as u64) as i32).collect(),
        vec![batch, seq],
    );
    let mut inputs: Vec<Tensor> = state[..art.n_params].to_vec();
    inputs.push(tokens);
    let out = fwd.execute(&inputs).unwrap();
    assert_eq!(out[0].shape(), &[batch, seq, vocab]);
    let logits = out[0].as_f32().unwrap();
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn shape_mismatch_is_rejected() {
    let art = require_artifacts!();
    let engine = Engine::cpu().unwrap();
    let init = engine.load(&art, "init").unwrap();
    // wrong dtype
    assert!(init.execute(&[Tensor::scalar_i32(0)]).is_err());
    // wrong arity
    assert!(init
        .execute(&[Tensor::scalar_u32(0), Tensor::scalar_u32(0)])
        .is_err());
}
