//! Cross-module integration of the analytical stack: the netsim validates
//! the Hockney abstractions the perf engine uses, and measured network
//! derates feed back into cluster parameters.

use lumos::collectives as coll;
use lumos::netsim::{measure_a2a_efficiency, replay_schedule, Network};
use lumos::perf::{evaluate, PerfKnobs};
use lumos::model::{MoeConfig, Workload};
use lumos::parallel::{Mapping, Parallelism};
use lumos::topology::cluster::{Cluster, Domain, DomainSpec};

#[test]
fn netsim_validates_hockney_allreduce_at_pod_scale() {
    // 64-GPU slice of a Passage pod (flow-level sim is O(flows²)-ish, so
    // validate on a slice; the algebra is scale-free).
    let n = 64;
    let gbps = 32_000.0;
    let bytes = 256e6;
    let net = Network::sls(n, gbps, 200e-9);
    let sched = coll::ring_all_reduce_schedule(n, bytes);
    let sim = replay_schedule(&net, &sched);
    let dom = DomainSpec {
        name: "passage".into(),
        gbps_per_gpu: gbps,
        latency_s: 200e-9,
        a2a_efficiency: 1.0,
    };
    let model = coll::all_reduce_time(&dom, n, bytes);
    let err = (sim.makespan - model).abs() / model;
    assert!(err < 0.05, "sim {} model {} err {}", sim.makespan, model, err);
}

#[test]
fn netsim_justifies_scaleout_a2a_derate() {
    // The cluster spec derates dense pod-crossing all-to-all to
    // a2a_efficiency ~ 0.6 of NIC line rate; measure it: 4 pods x 16
    // GPUs, 1.6 Tb/s NICs, 2:1 oversubscribed pod uplinks.
    let n = 64;
    let pod = 16;
    let bytes = 2e9;
    let net = Network::cluster(n, pod, 14_400.0, 1_600.0, 2.0, 5e-6);
    let sched = coll::pairwise_a2a_schedule(n, bytes);
    let sim = replay_schedule(&net, &sched);
    // Baseline: cross-pod share streamed at full NIC rate.
    let cross = bytes * (n - pod) as f64 / (n - 1) as f64;
    let ideal = cross / (1_600.0 * 1e9 / 8.0);
    let eff = ideal / sim.makespan;
    // 2:1 oversubscription caps it at 0.5; barriers shave a bit more.
    assert!(eff > 0.25 && eff < 0.65, "measured {eff}");
}

#[test]
fn in_pod_a2a_needs_no_derate() {
    // Large messages: in-pod SLS all-to-all runs at ~line rate.
    let net = Network::sls(64, 32_000.0, 200e-9);
    let eff = measure_a2a_efficiency(&net, 64, 1e9);
    assert!(eff > 0.9, "measured {eff}");
}

#[test]
fn perf_engine_is_scale_consistent() {
    // Halving per-GPU work by doubling DP (same cluster) must not increase
    // step time; TTT stays within 2x (comm terms shift).
    let w = Workload::paper_gpt_4p7t(2);
    let cluster = Cluster::passage_512(32_768);
    let knobs = PerfKnobs::default();
    let m1 = Mapping::new(Parallelism { tp: 16, pp: 8, dp: 256 }, MoeConfig::paper_config(2));
    let r1 = evaluate(&w, &cluster, &m1, &knobs);
    let m2 = Mapping::new(Parallelism { tp: 16, pp: 4, dp: 512 }, MoeConfig::paper_config(2));
    let r2 = evaluate(&w, &cluster, &m2, &knobs);
    assert!(r2.step_time < r1.step_time, "{} vs {}", r2.step_time, r1.step_time);
}

#[test]
fn domain_assignment_matches_collective_costs() {
    // A TP-sized group must be cheaper in-pod than the same bytes over the
    // scale-out fabric — the whole premise of TP-first placement.
    let c = Cluster::electrical_144(144 * 4);
    let up = c.domain(Domain::ScaleUp);
    let out = c.domain(Domain::ScaleOut);
    let bytes = 100e6;
    assert!(coll::all_reduce_time(up, 16, bytes) < coll::all_reduce_time(out, 16, bytes) / 3.0);
}

#[test]
fn schedule_replay_and_closed_form_agree_for_allgather() {
    let n = 32;
    let bytes = 128e6;
    let net = Network::sls(n, 14_400.0, 0.0);
    let sched = coll::ring_all_gather_schedule(n, bytes);
    let sim = replay_schedule(&net, &sched);
    let dom = DomainSpec {
        name: "e".into(),
        gbps_per_gpu: 14_400.0,
        latency_s: 0.0,
        a2a_efficiency: 1.0,
    };
    let model = coll::all_gather_time(&dom, n, bytes);
    assert!((sim.makespan - model).abs() / model < 0.02);
}
