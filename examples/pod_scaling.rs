//! Pod-scaling study: sweep scale-up pod size and per-GPU bandwidth to map
//! where the expert-parallel spill cliff sits and where extra bandwidth
//! stops paying — the generalization of Figures 10/11 that a system
//! architect would actually run.
//!
//! Run: `cargo run --release --example pod_scaling`

use lumos::perf::{evaluate_paper_config, PerfKnobs};
use lumos::topology::cluster::Cluster;
use lumos::util::table::Table;

fn main() {
    let knobs = PerfKnobs::default();

    // 2D sweep: pod size × bandwidth, Config 4 step time (normalized).
    let pods = [72usize, 144, 256, 512, 1024];
    let bws = [7_200.0, 14_400.0, 32_000.0, 64_000.0];
    let base = evaluate_paper_config(&Cluster::custom(32_768, 512, 32_000.0), 4, &knobs).step_time;

    let mut header: Vec<String> = vec!["pod \\ Gb/s".into()];
    header.extend(bws.iter().map(|b| format!("{:.1}T", b / 1000.0)));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Config 4 step time vs (pod size, scale-up bandwidth), normalized to 512@32T",
        &header_refs,
    );
    for &pod in &pods {
        let mut row = vec![format!("{pod}")];
        for &bw in &bws {
            let n = 32_768 / pod * pod;
            let r = evaluate_paper_config(&Cluster::custom(n, pod, bw), 4, &knobs);
            let marker = match r.breakdown.ep_placement {
                lumos::perf::EpPlacement::ScaleUp => "",
                lumos::perf::EpPlacement::Hierarchical => "*",
            };
            row.push(format!("{:.2}{}", r.step_time / base, marker));
        }
        t.row(&row);
    }
    println!("{}", t.render());
    println!("(* = EP group spills across pods onto Ethernet)\n");

    // Where does the cliff sit? EP group needs ep_dp_ranks × tp = 512 GPUs.
    println!(
        "The cliff: the paper's EP group spans 32 DP ranks x TP 16 = 512 GPUs, so any\n\
         pod smaller than 512 pushes expert all-to-all onto the scale-out network.\n\
         Radix (not just bandwidth) is what the 3D optics buy (paper §VI)."
    );

    // Diminishing returns of bandwidth once EP fits.
    println!("\n{}", lumos::sweep::bandwidth_sweep(&knobs).render());
}
