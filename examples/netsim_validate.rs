//! Netsim validation study: the paper's performance model stands on the
//! Hockney α+β abstraction (§V.A). This example stress-tests it against
//! the flow-level simulator:
//!
//! 1. collective schedules on a non-blocking SLS pod (model should match),
//! 2. dense all-to-all crossing an oversubscribed scale-out fabric (model
//!    needs the a2a_efficiency derate — we *measure* that derate here; it
//!    is where the DomainSpec default of 0.6 comes from),
//! 3. incast pathologies that no α+β model captures.
//!
//! Run: `cargo run --release --example netsim_validate`

use lumos::collectives as coll;
use lumos::netsim::{replay_schedule, simulate, Network};
use lumos::topology::cluster::DomainSpec;
use lumos::util::stats::fmt_time;
use lumos::util::table::Table;

fn main() {
    // ---- 1. Hockney vs sim on a Passage-like SLS pod slice -------------
    let n = 64;
    let net = Network::sls(n, 32_000.0, 200e-9);
    let dom = DomainSpec {
        name: "passage".into(),
        gbps_per_gpu: 32_000.0,
        latency_s: 200e-9,
        a2a_efficiency: 1.0,
    };
    let mut t = Table::new(
        "Hockney model vs flow-level simulation (64-GPU SLS @ 32 Tb/s)",
        &["collective", "bytes", "model", "simulated", "error"],
    );
    for mb in [16.0, 64.0, 256.0] {
        let bytes = mb * 1e6;
        let cases: Vec<(&str, coll::CommSchedule, f64)> = vec![
            ("ring all-reduce", coll::ring_all_reduce_schedule(n, bytes),
             coll::all_reduce_time(&dom, n, bytes)),
            ("ring all-gather", coll::ring_all_gather_schedule(n, bytes),
             coll::all_gather_time(&dom, n, bytes)),
            ("pairwise all-to-all", coll::pairwise_a2a_schedule(n, bytes),
             coll::all_to_all_time(&dom, n, bytes)),
        ];
        for (name, sched, model) in cases {
            let sim = replay_schedule(&net, &sched);
            t.row(&[
                name.to_string(),
                format!("{mb:.0} MB"),
                fmt_time(model),
                fmt_time(sim.makespan),
                format!("{:+.1}%", 100.0 * (sim.makespan - model) / model),
            ]);
        }
    }
    println!("{}", t.render());

    // ---- 2. measure the scale-out a2a derate -----------------------------
    let mut t2 = Table::new(
        "Cross-pod all-to-all efficiency vs oversubscription (16-GPU pods, 1.6T NICs)",
        &["oversubscription", "effective NIC utilization"],
    );
    for oversub in [1.0, 1.5, 2.0, 4.0] {
        let pods = 4;
        let pod = 16;
        let nn = pods * pod;
        let bytes = 2e9;
        let cnet = Network::cluster(nn, pod, 14_400.0, 1_600.0, oversub, 5e-6);
        let sched = coll::pairwise_a2a_schedule(nn, bytes);
        let sim = replay_schedule(&cnet, &sched);
        let cross = bytes * (nn - pod) as f64 / (nn - 1) as f64;
        let eff = cross / (1_600.0 * 1e9 / 8.0) / sim.makespan;
        t2.row(&[format!("{oversub:.1}:1"), format!("{:.2}", eff)]);
    }
    println!("{}", t2.render());
    println!(
        "The DomainSpec scale-out a2a_efficiency default (0.6) corresponds to the\n\
         ~1.5:1 row; heavier oversubscription degrades further — exactly the\n\
         regime the paper's 144-pod alternative is forced into.\n"
    );

    // ---- 3. incast: the α+β blind spot ----------------------------------
    let inc = Network::sls(9, 32_000.0, 200e-9);
    let flows: Vec<_> = (1..9).map(|s| inc.flow(s, 0, 100e6)).collect();
    let r = simulate(&inc, &flows);
    let one = simulate(&inc, &[inc.flow(1, 0, 100e6)]);
    println!(
        "Incast (8 senders -> 1 receiver, 100 MB each): {} vs {} for one flow\n\
         ({}x — the ejection port serializes; Hockney would predict {}x only\n\
         with a perfect congestion derate).",
        fmt_time(r.makespan),
        fmt_time(one.makespan),
        (r.makespan / one.makespan).round(),
        8
    );
}
