//! Quickstart: evaluate the paper's headline result in ~20 lines.
//!
//! Builds the two §VI systems (Passage 512-GPU pods @ 32 Tb/s vs the
//! electrical 144-GPU pods @ 14.4 Tb/s), runs the analytical time-to-train
//! model on MoE Config 4 (256 experts, top-8, granularity 8), and prints
//! the speedup — the paper's 2.7×.
//!
//! Run: `cargo run --release --example quickstart`

use lumos::perf::{evaluate_paper_config, paper_clusters, PerfKnobs};
use lumos::util::stats::fmt_time;

fn main() {
    let knobs = PerfKnobs::default();
    let (passage, _alt512, alt144) = paper_clusters();

    println!("MoE 4.7T-parameter training, 32,768 GPUs, 13T tokens (paper §VI)\n");
    println!(
        "{:<10} {:>22} {:>22} {:>9}",
        "config", "Passage-512 @32T", "Electrical-144 @14.4T", "speedup"
    );
    for cfg in 1..=4 {
        let p = evaluate_paper_config(&passage, cfg, &knobs);
        let a = evaluate_paper_config(&alt144, cfg, &knobs);
        println!(
            "Config {:<3} {:>22} {:>22} {:>8.2}x",
            cfg,
            fmt_time(p.time_to_train_s),
            fmt_time(a.time_to_train_s),
            a.time_to_train_s / p.time_to_train_s
        );
    }

    let p = evaluate_paper_config(&passage, 4, &knobs);
    let a = evaluate_paper_config(&alt144, 4, &knobs);
    println!(
        "\nConfig 4: expert all-to-all rides the {} on Passage ({:?}) but spills \
         to Ethernet on the electrical pod ({:?}) — {:.1}% vs {:.1}% of the step \
         spent communicating.",
        passage.spec.scale_up.name,
        p.breakdown.ep_placement,
        a.breakdown.ep_placement,
        100.0 * p.comm_fraction,
        100.0 * a.comm_fraction,
    );
}
