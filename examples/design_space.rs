//! Design-space exploration (paper §IV): energy, power and area of LPO,
//! CPO and Passage scale-up designs — Tables II/III and Figures 7/8 —
//! plus switch-package feasibility (§IV.C.b).
//!
//! Run: `cargo run --release --example design_space`

use lumos::hw;

fn main() {
    // Table III: pJ/bit decomposition.
    println!("{}", lumos::sweep::table3().render());

    // Fig 7: power at the 2028 GPU design point.
    let (t7, c7) = lumos::sweep::fig7();
    println!("{}\n{}", t7.render(), c7.render());

    // Fig 8: area accounting.
    let (t8, c8) = lumos::sweep::fig8();
    println!("{}\n{}", t8.render(), c8.render());

    // Switch design: shoreline vs area I/O (§IV.C.b).
    let sw = hw::SwitchPackage::sls_512();
    println!("## Switch package (200 Tb/s, 512 x 448G ports)");
    for tech in [hw::lpo_dr8(), hw::cpo_2p5d(), hw::passage_interposer()] {
        println!(
            "  {:<32} -> {} reticles (shoreline need {:.0} mm), fabric power {:.2} kW",
            tech.name,
            sw.reticles_needed(&tech),
            sw.required_shoreline_mm(&tech.serdes),
            tech.power_w(sw.fabric_gbps) / 1000.0,
        );
    }
    println!(
        "  Passage saves {:.2} kW per switch vs CPO (paper: ~1.5 kW)",
        sw.power_saving_w(&hw::cpo_2p5d(), &hw::passage_interposer()) / 1000.0
    );

    // Reach limits (§II.C.2): why copper caps the pod at a rack.
    println!("\n## Reach");
    for t in [hw::dac_copper(), hw::lpo_dr8(), hw::passage_interposer()] {
        println!("  {:<32} reach {:>6.1} m", t.name, t.reach_m);
    }
}
