//! Mapping-space search on a fabric the paper never evaluated: what
//! parallelism would you actually run on *this* cluster?
//!
//! The paper fixes TP 16 × PP 8 × DP 256 everywhere; the planner frees all
//! five mapping dimensions (TP, PP, DP, microbatch, experts-per-rank),
//! prunes everything that breaks divisibility or HBM capacity, and ranks
//! the survivors by time-to-train. Here we plan a 4,096-GPU cluster with
//! 256-GPU pods at 24 Tb/s — between the paper's two design points.
//!
//! Run: `cargo run --release --example plan_search`

use lumos::perf::PerfKnobs;
use lumos::planner::{plan, ranked_table, PlanRequest};
use lumos::sweep::engine::ClusterKey;

fn main() {
    let knobs = PerfKnobs::default();
    let cluster = ClusterKey::custom(4_096, 256, 24_000.0);

    // Config 2 (64 experts, top-2): the EP group needs ep_dp_ranks x tp
    // GPUs, so experts-per-rank decides whether expert all-to-all stays
    // inside the 256-GPU pod or spills onto Ethernet.
    let req = PlanRequest::paper(cluster, 2, &knobs).with_top(8);
    let out = plan(&req, 4);

    println!(
        "searched {} legal mappings, pruned {} (HBM), ranked {}\n",
        out.enumerated,
        out.pruned,
        out.ranked.len()
    );
    println!("{}", ranked_table(&out).render());

    let best = out.best().expect("a 4k-GPU cluster has feasible mappings");
    println!(
        "Winner: TP{} x PP{} x DP{}, {} seq/microbatch, {} experts/rank — EP rides {:?}.",
        best.mapping.par.tp,
        best.mapping.par.pp,
        best.mapping.par.dp,
        best.mapping.microbatch_seqs,
        best.mapping.moe.experts_per_dp_rank,
        best.report.breakdown.ep_placement,
    );
    match best.report.breakdown.ep_placement {
        lumos::perf::EpPlacement::ScaleUp => println!(
            "The planner keeps the expert group inside the pod ({} GPUs <= 256 pod) by\n\
             co-locating experts, instead of inheriting the paper's fixed mapping.",
            best.mapping.ep_span_gpus(),
        ),
        lumos::perf::EpPlacement::Hierarchical => println!(
            "Even the best mapping spills the expert group across pods ({} GPUs > 256 pod)\n\
             — this fabric is radix-limited for this MoE shape.",
            best.mapping.ep_span_gpus(),
        ),
    }
}
