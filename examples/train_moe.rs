//! End-to-end validation driver (DESIGN.md deliverable (b)/§E2E): train a
//! real MoE transformer — Pallas kernels → JAX model → AOT HLO → Rust PJRT
//! runtime → Rust data-parallel coordinator — on a synthetic Markov corpus
//! and log the loss curve.
//!
//! All three layers compose here with Python nowhere on the path.
//!
//! Run (CI-size):   cargo run --release --example train_moe
//! Full E2E run:    cargo run --release --example train_moe -- e2e 300 2
//!                  (preset, steps, dp-workers; ~105M params)

use lumos::runtime::{artifacts_root, Artifact, Engine};
use lumos::trainer::{train_dp, train_single, Corpus};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("tiny").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);

    let art = Artifact::load(artifacts_root()?.join(&preset))?;
    let engine = Engine::cpu()?;
    let vocab = art.cfg_usize("vocab")?;
    let corpus = Corpus::markov(vocab, 42 ^ 0xC0FFEE);

    println!(
        "== LUMOS end-to-end MoE training ==\n\
         preset          : {preset}\n\
         parameters      : {:.1} M ({} arrays)\n\
         experts         : {} (top-{})\n\
         corpus          : Markov chain over {} tokens, entropy {:.2} nats/tok\n\
         uniform ceiling : {:.2} nats/tok\n\
         steps x workers : {steps} x {workers}\n",
        art.total_param_elements as f64 / 1e6,
        art.n_params,
        art.cfg_usize("n_experts")?,
        art.cfg_usize("top_k")?,
        vocab,
        corpus.entropy_rate(),
        (vocab as f64).ln(),
    );

    let report = if workers <= 1 {
        train_single(&engine, &art, steps, 42, true)?
    } else {
        train_dp(&engine, &art, workers, steps, 42, true)?
    };

    // Render the loss curve as a terminal sparkline.
    let losses: Vec<f64> = report.steps.iter().map(|s| s.ce_loss).collect();
    let lo = losses.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = losses.iter().cloned().fold(0.0f64, f64::max);
    let glyphs = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let spark: String = losses
        .iter()
        .map(|&l| glyphs[(((l - lo) / (hi - lo).max(1e-9)) * 7.0).round() as usize])
        .collect();
    println!("\nloss curve ({} steps): {spark}", losses.len());
    println!(
        "ce {:.4} -> {:.4}  (corpus entropy floor ~{:.2})",
        report.first_loss(),
        report.last_loss(),
        corpus.entropy_rate()
    );
    println!(
        "steady step: {:.2}s; total {:.1}s; comm/step: {:.1} MB",
        report.steady_step_secs(),
        report.total_secs,
        report.steps.last().map_or(0.0, |s| s.comm_bytes as f64 / 1e6),
    );

    let csv_path = format!("train_{preset}_{}w.csv", workers);
    std::fs::write(&csv_path, report.to_csv())?;
    println!("loss curve CSV -> {csv_path}");

    anyhow::ensure!(
        report.last_loss() < report.first_loss(),
        "training did not reduce the loss"
    );
    Ok(())
}
