"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Includes hypothesis sweeps over shapes/dtypes per the reproduction brief:
every sampled configuration is checked with assert_allclose against ref.py,
forward AND backward (custom Pallas VJP kernels).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, moe_ffn
from compile.kernels import ref as kref
from compile.kernels.moe_ffn import mxu_flops, vmem_bytes as moe_vmem_bytes
from compile.kernels.flash_attention import vmem_bytes as fa_vmem_bytes

RTOL, ATOL = 2e-5, 2e-5


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def _moe_operands(key, e, c, d, f, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    return (
        _rand(ks[0], (e, c, d), dtype),
        _rand(ks[1], (e, d, f), dtype, 0.1),
        _rand(ks[2], (e, f), dtype, 0.01),
        _rand(ks[3], (e, f, d), dtype, 0.1),
        _rand(ks[4], (e, d), dtype, 0.01),
    )


# ---------------------------------------------------------------- moe_ffn


class TestMoeFfnForward:
    def test_matches_ref_basic(self):
        ops = _moe_operands(jax.random.PRNGKey(0), 4, 64, 32, 48)
        np.testing.assert_allclose(
            moe_ffn(*ops, block_c=16), kref.moe_ffn_ref(*ops),
            rtol=RTOL, atol=ATOL)

    def test_single_expert(self):
        ops = _moe_operands(jax.random.PRNGKey(1), 1, 32, 16, 16)
        np.testing.assert_allclose(
            moe_ffn(*ops, block_c=32), kref.moe_ffn_ref(*ops),
            rtol=RTOL, atol=ATOL)

    def test_block_equals_capacity(self):
        ops = _moe_operands(jax.random.PRNGKey(2), 3, 48, 8, 24)
        np.testing.assert_allclose(
            moe_ffn(*ops, block_c=48), kref.moe_ffn_ref(*ops),
            rtol=RTOL, atol=ATOL)

    def test_zero_inputs_give_bias_path(self):
        e, c, d, f = 2, 16, 8, 8
        ops = _moe_operands(jax.random.PRNGKey(3), e, c, d, f)
        x0 = jnp.zeros_like(ops[0])
        got = moe_ffn(x0, *ops[1:], block_c=16)
        want = kref.moe_ffn_ref(x0, *ops[1:])
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_rejects_bad_capacity_tiling(self):
        ops = _moe_operands(jax.random.PRNGKey(4), 2, 40, 8, 8)
        with pytest.raises(ValueError, match="multiple of block_c"):
            moe_ffn(*ops, block_c=16)

    def test_rejects_bad_weight_shapes(self):
        x, w1, b1, w2, b2 = _moe_operands(jax.random.PRNGKey(5), 2, 16, 8, 8)
        with pytest.raises(ValueError, match="w2 shape"):
            moe_ffn(x, w1, b1, w2[:, :4, :], b2, block_c=16)

    @settings(max_examples=20, deadline=None)
    @given(
        e=st.integers(1, 6),
        nc=st.integers(1, 4),
        bc=st.sampled_from([8, 16, 32]),
        d=st.sampled_from([8, 16, 32, 64]),
        f=st.sampled_from([8, 24, 64, 96]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, e, nc, bc, d, f, seed):
        c = nc * bc
        ops = _moe_operands(jax.random.PRNGKey(seed), e, c, d, f)
        np.testing.assert_allclose(
            moe_ffn(*ops, block_c=bc), kref.moe_ffn_ref(*ops),
            rtol=RTOL, atol=ATOL)


class TestMoeFfnBackward:
    def _grads(self, fn, ops):
        return jax.grad(lambda a: jnp.sum(jnp.sin(fn(*a))))(ops)

    def test_grads_match_ref(self):
        ops = _moe_operands(jax.random.PRNGKey(10), 3, 32, 16, 24)
        gk = self._grads(lambda *a: moe_ffn(*a, block_c=16), ops)
        gr = self._grads(kref.moe_ffn_ref, ops)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)

    def test_weight_grad_accumulates_over_blocks(self):
        # C spans several blocks: dw must sum contributions (revisit path).
        ops = _moe_operands(jax.random.PRNGKey(11), 2, 64, 8, 8)
        gk = self._grads(lambda *a: moe_ffn(*a, block_c=8), ops)
        gr = self._grads(kref.moe_ffn_ref, ops)
        np.testing.assert_allclose(gk[1], gr[1], rtol=5e-4, atol=5e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        e=st.integers(1, 4),
        nc=st.integers(1, 3),
        bc=st.sampled_from([8, 16]),
        d=st.sampled_from([8, 16]),
        f=st.sampled_from([8, 24]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_grad_sweep(self, e, nc, bc, d, f, seed):
        ops = _moe_operands(jax.random.PRNGKey(seed), e, nc * bc, d, f)
        gk = self._grads(lambda *a: moe_ffn(*a, block_c=bc), ops)
        gr = self._grads(kref.moe_ffn_ref, ops)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


# ------------------------------------------------------- flash_attention


def _qkv(key, bh, s, dh, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return tuple(_rand(k, (bh, s, dh), dtype) for k in ks)


class TestFlashAttentionForward:
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, causal):
        q, k, v = _qkv(jax.random.PRNGKey(0), 4, 64, 16)
        np.testing.assert_allclose(
            flash_attention(q, k, v, causal=causal, block_q=16, block_k=16),
            kref.attention_ref(q, k, v, causal=causal),
            rtol=RTOL, atol=ATOL)

    def test_asymmetric_blocks(self):
        q, k, v = _qkv(jax.random.PRNGKey(1), 2, 64, 8)
        np.testing.assert_allclose(
            flash_attention(q, k, v, block_q=16, block_k=32),
            kref.attention_ref(q, k, v), rtol=RTOL, atol=ATOL)

    def test_single_block(self):
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 32, 8)
        np.testing.assert_allclose(
            flash_attention(q, k, v, block_q=32, block_k=32),
            kref.attention_ref(q, k, v), rtol=RTOL, atol=ATOL)

    def test_custom_scale(self):
        q, k, v = _qkv(jax.random.PRNGKey(3), 2, 32, 8)
        np.testing.assert_allclose(
            flash_attention(q, k, v, scale=0.5, block_q=16, block_k=16),
            kref.attention_ref(q, k, v, scale=0.5), rtol=RTOL, atol=ATOL)

    def test_large_magnitude_stability(self):
        q, k, v = (50.0 * t for t in _qkv(jax.random.PRNGKey(4), 2, 32, 8))
        got = flash_attention(q, k, v, block_q=16, block_k=16)
        assert bool(jnp.all(jnp.isfinite(got)))

    def test_rejects_bad_seq_tiling(self):
        q, k, v = _qkv(jax.random.PRNGKey(5), 1, 48, 8)
        with pytest.raises(ValueError, match="not a multiple"):
            flash_attention(q, k, v, block_q=32, block_k=32)

    @settings(max_examples=20, deadline=None)
    @given(
        bh=st.integers(1, 6),
        nblk=st.integers(1, 4),
        blk=st.sampled_from([8, 16, 32]),
        dh=st.sampled_from([4, 8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, bh, nblk, blk, dh, causal, seed):
        s = nblk * blk
        q, k, v = _qkv(jax.random.PRNGKey(seed), bh, s, dh)
        np.testing.assert_allclose(
            flash_attention(q, k, v, causal=causal, block_q=blk, block_k=blk),
            kref.attention_ref(q, k, v, causal=causal),
            rtol=5e-5, atol=5e-5)


class TestFlashAttentionBackward:
    def _grads(self, fn, ops):
        return jax.grad(lambda a: jnp.sum(jnp.cos(fn(*a))))(ops)

    @pytest.mark.parametrize("causal", [True, False])
    def test_grads_match_ref(self, causal):
        ops = _qkv(jax.random.PRNGKey(10), 3, 64, 16)
        gk = self._grads(lambda *a: flash_attention(
            *a, causal=causal, block_q=16, block_k=16), ops)
        gr = self._grads(lambda *a: kref.attention_ref(*a, causal=causal), ops)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        bh=st.integers(1, 3),
        nblk=st.integers(1, 3),
        blk=st.sampled_from([8, 16]),
        dh=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_grad_sweep(self, bh, nblk, blk, dh, seed):
        ops = _qkv(jax.random.PRNGKey(seed), bh, nblk * blk, dh)
        gk = self._grads(lambda *a: flash_attention(
            *a, block_q=blk, block_k=blk), ops)
        gr = self._grads(kref.attention_ref, ops)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


# --------------------------------------------------- static perf estimates


class TestStaticEstimates:
    def test_moe_vmem_positive_and_monotonic(self):
        a = moe_vmem_bytes(128, 512, 1408)
        b = moe_vmem_bytes(256, 512, 1408)
        assert 0 < a < b

    def test_moe_mxu_flops(self):
        assert mxu_flops(2, 4, 8, 16) == 2 * 2 * 4 * (8 * 16 * 2)

    def test_flash_vmem(self):
        assert fa_vmem_bytes(64, 64, 128, 64) > 0
