"""AOT path tests: flat entrypoints == pytree entrypoints; manifest and HLO
text artifacts are well-formed and mutually consistent."""

import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

CFG = M.TINY


def _tokens(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    return jax.random.randint(k, (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab)


class TestFlatEntrypoints:
    def setup_method(self):
        self.eps = aot.make_entrypoints(CFG)
        self.names = M.param_names(CFG)
        self.p = len(self.names)

    def test_init_flat_matches_pytree(self):
        flat = self.eps["init"](jnp.uint32(3))
        assert len(flat) == 3 * self.p + 1
        params, _, _, step = M.init_state(CFG, jnp.uint32(3))
        np.testing.assert_array_equal(flat[0], params[self.names[0]])
        assert int(flat[-1]) == 0

    def test_train_step_flat_matches_pytree(self):
        state = M.init_state(CFG, 0)
        toks = _tokens(CFG)
        flat_state = (tuple(state[0][n] for n in self.names)
                      + tuple(state[1][n] for n in self.names)
                      + tuple(state[2][n] for n in self.names) + (state[3],))
        out = self.eps["train_step"](*flat_state, toks)
        assert len(out) == 3 * self.p + 1 + 2
        _, ce_ref, _ = M.train_step(CFG, state, toks)
        assert float(out[-2]) == pytest.approx(float(ce_ref), rel=1e-5)

    def test_grad_apply_composition(self):
        state = M.init_state(CFG, 0)
        toks = _tokens(CFG)
        flat_params = tuple(state[0][n] for n in self.names)
        gout = self.eps["grad_step"](*flat_params, toks)
        grads, ce = gout[:self.p], gout[self.p]
        flat_state = (flat_params
                      + tuple(state[1][n] for n in self.names)
                      + tuple(state[2][n] for n in self.names) + (state[3],))
        new_state = self.eps["apply_update"](*flat_state, *grads)
        assert len(new_state) == 3 * self.p + 1
        s1, ce1, _ = M.train_step(CFG, state, toks)
        np.testing.assert_allclose(new_state[0], s1[0][self.names[0]],
                                   rtol=1e-5, atol=1e-6)

    def test_forward_flat(self):
        params = M.init_params(CFG, 0)
        flat_params = tuple(params[n] for n in self.names)
        toks = _tokens(CFG)[:, :-1]
        logits, aux = self.eps["forward"](*flat_params, toks)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)


class TestSpecs:
    def test_example_args_match_io_specs(self):
        for entry in aot.DEFAULT_ENTRIES:
            args = aot.example_args(CFG, entry)
            ins, outs = aot.io_specs(CFG, entry)
            assert len(args) == len(ins), entry
            for a, s in zip(args, ins):
                assert list(a.shape) == s["shape"], (entry, s["name"])

    def test_output_spec_shapes(self):
        _, outs = aot.io_specs(CFG, "forward")
        assert outs[0]["shape"] == [CFG.batch, CFG.seq_len, CFG.vocab]


class TestBuildArtifacts:
    @pytest.fixture(scope="class")
    def outdir(self, tmp_path_factory):
        d = tmp_path_factory.mktemp("artifacts") / "tiny"
        aot.build(CFG, str(d), entries=("init", "train_step"), verbose=False)
        return str(d)

    def test_files_written(self, outdir):
        assert os.path.exists(os.path.join(outdir, "manifest.json"))
        assert os.path.exists(os.path.join(outdir, "init.hlo.txt"))
        assert os.path.exists(os.path.join(outdir, "train_step.hlo.txt"))

    def test_hlo_text_is_hlo(self, outdir):
        with open(os.path.join(outdir, "train_step.hlo.txt")) as fh:
            head = fh.read(200)
        assert head.startswith("HloModule")

    def test_manifest_consistency(self, outdir):
        with open(os.path.join(outdir, "manifest.json")) as fh:
            man = json.load(fh)
        assert man["format"] == "hlo-text-v1"
        assert man["n_params"] == len(M.param_names(CFG))
        assert man["total_param_elements"] == M.count_params(CFG)
        assert man["param_names"] == sorted(man["param_names"])
        ts = man["entrypoints"]["train_step"]
        # state (3P+1) + tokens in; state + ce + aux out
        p = man["n_params"]
        assert len(ts["inputs"]) == 3 * p + 2
        assert len(ts["outputs"]) == 3 * p + 3
        total = sum(math.prod(s["shape"]) for s in man["params"])
        assert total == man["total_param_elements"]

    def test_manifest_roundtrips_config(self, outdir):
        with open(os.path.join(outdir, "manifest.json")) as fh:
            man = json.load(fh)
        cfg2 = M.ModelConfig(**man["config"])
        assert cfg2 == CFG
