"""L2 model tests: routing invariants, shapes, training dynamics, and the
pallas-vs-reference equivalence of the whole forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M


CFG = M.TINY
CFG_REF = M.TINY.__class__(**{**M.TINY.to_dict(), "use_pallas": False})


def _tokens(cfg, seed=0, extra=1):
    k = jax.random.PRNGKey(seed)
    return jax.random.randint(k, (cfg.batch, cfg.seq_len + extra), 0, cfg.vocab)


class TestParams:
    def test_param_count_formula(self):
        cfg = M.TINY
        d, f, e, L = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.n_layers
        expected = (cfg.vocab * d + cfg.seq_len * d + 2 * d
                    + L * (4 * d * d + 4 * d + d * e
                           + e * (d * f + f + f * d + d)))
        assert M.count_params(cfg) == expected

    def test_param_names_sorted_and_stable(self):
        names = M.param_names(CFG)
        assert names == sorted(names)
        assert names == M.param_names(CFG)

    def test_init_shapes_match_spec(self):
        p = M.init_params(CFG, 0)
        shapes = M.param_shapes(CFG)
        assert set(p) == set(shapes)
        for k, v in p.items():
            assert v.shape == shapes[k], k

    def test_init_deterministic_in_seed(self):
        a = M.init_params(CFG, 7)
        b = M.init_params(CFG, 7)
        c = M.init_params(CFG, 8)
        np.testing.assert_array_equal(a["tok_emb"], b["tok_emb"])
        assert not np.allclose(a["tok_emb"], c["tok_emb"])

    def test_e2e_preset_is_about_100m(self):
        assert 80e6 < M.count_params(M.E2E) < 150e6


class TestRouting:
    def _route(self, cfg, logits):
        return M._route(cfg, logits)

    def test_dispatch_entries_are_binary(self):
        logits = jax.random.normal(jax.random.PRNGKey(0),
                                   (CFG.n_tokens, CFG.n_experts))
        d, c, aux, _ = self._route(CFG, logits)
        vals = np.unique(np.asarray(d))
        assert set(vals).issubset({0.0, 1.0})

    def test_token_conservation_no_drops(self):
        # Round-robin peaks: expert (i mod E) then ((i+1) mod E) per token,
        # so each expert receives exactly 2N/E <= capacity tokens.
        n, e = CFG.n_tokens, CFG.n_experts
        idx = np.arange(n)
        logits = np.full((n, e), -8.0, np.float32)
        logits[idx, idx % e] = 8.0
        logits[idx, (idx + 1) % e] = 4.0
        assert 2 * n // e <= CFG.capacity
        d, _, _, stats = self._route(CFG, jnp.asarray(logits))
        assert float(jnp.sum(d)) == n * CFG.top_k
        assert int(stats["dropped"]) == 0

    def test_capacity_overflow_drops(self):
        # All tokens to expert 0 -> overflow beyond capacity must drop.
        logits = jnp.full((CFG.n_tokens, CFG.n_experts), -10.0)
        logits = logits.at[:, 0].set(10.0)
        d, _, _, stats = self._route(CFG, logits)
        per_expert = jnp.sum(d, axis=(0, 2))
        assert float(per_expert[0]) == CFG.capacity
        assert int(stats["dropped"]) > 0

    def test_combine_rows_sum_to_gate_mass(self):
        logits = jax.random.normal(jax.random.PRNGKey(1),
                                   (CFG.n_tokens, CFG.n_experts))
        d, c, _, stats = self._route(CFG, logits)
        row = jnp.sum(c, axis=(1, 2))
        assert float(jnp.max(row)) <= 1.0 + 1e-5
        if int(stats["dropped"]) == 0:
            np.testing.assert_allclose(row, 1.0, rtol=1e-5)

    def test_no_capacity_slot_double_booked(self):
        logits = jax.random.normal(jax.random.PRNGKey(2),
                                   (CFG.n_tokens, CFG.n_experts))
        d, _, _, _ = self._route(CFG, logits)
        slot_occ = jnp.sum(d, axis=0)   # [E, C]
        assert float(jnp.max(slot_occ)) <= 1.0 + 1e-6

    def test_aux_loss_minimal_when_balanced(self):
        balanced = jnp.zeros((CFG.n_tokens, CFG.n_experts))
        skewed = balanced.at[:, 0].set(5.0)
        *_, aux_b, _ = self._route(CFG, balanced)
        *_, aux_s, _ = self._route(CFG, skewed)
        assert float(aux_b) <= float(aux_s)
        assert float(aux_b) == pytest.approx(1.0, rel=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 5.0))
    def test_hypothesis_dispatch_bounded(self, seed, scale):
        logits = scale * jax.random.normal(
            jax.random.PRNGKey(seed), (CFG.n_tokens, CFG.n_experts))
        d, c, aux, stats = self._route(CFG, logits)
        # dispatched slots never exceed N*k, never negative, aux finite
        total = float(jnp.sum(d))
        assert 0 <= total <= CFG.n_tokens * CFG.top_k
        assert total + float(stats["dropped"]) == CFG.n_tokens * CFG.top_k
        assert np.isfinite(float(aux))


class TestForward:
    def test_logits_shape(self):
        p = M.init_params(CFG, 0)
        toks = _tokens(CFG, extra=0)
        logits, aux = M.forward(CFG, p, toks)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
        assert np.isfinite(float(aux))

    def test_pallas_matches_reference_model(self):
        """Whole-model oracle: pallas kernels vs pure-jnp forward."""
        p = M.init_params(CFG, 0)
        toks = _tokens(CFG, extra=0)
        lp, ap = M.forward(CFG, p, toks)
        lr, ar = M.forward(CFG_REF, p, toks)
        np.testing.assert_allclose(lp, lr, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(ap, ar, rtol=2e-4, atol=2e-4)

    def test_grads_pallas_match_reference_model(self):
        p = M.init_params(CFG, 0)
        toks = _tokens(CFG)
        gp, _, _ = M.grad_step(CFG, p, toks)
        gr, _, _ = M.grad_step(CFG_REF, p, toks)
        worst = max(float(jnp.max(jnp.abs(gp[k] - gr[k]))) for k in gp)
        assert worst < 5e-3

    def test_causality(self):
        """Future-token perturbation must not change past logits.

        Note: with finite expert capacity, GShard dense dispatch is
        order-dependent (a later token's slot-0 routing shifts earlier
        tokens' slot-1 queue positions and can change who is dropped), so
        strict causality only holds drop-free. Use capacity >= N so no
        token can ever be dropped.
        """
        cfg = M.ModelConfig(**{**CFG.to_dict(), "capacity_factor": 4.0})
        assert cfg.capacity >= cfg.n_tokens
        p = M.init_params(cfg, 0)
        toks = np.asarray(_tokens(cfg, extra=0))
        l1, _ = M.forward(cfg, p, jnp.asarray(toks))
        toks2 = toks.copy()
        toks2[-1, -1] = (toks2[-1, -1] + 1) % cfg.vocab
        l2, _ = M.forward(cfg, p, jnp.asarray(toks2))
        np.testing.assert_allclose(l1[:-1], l2[:-1], rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(l1[-1, :-1], l2[-1, :-1], rtol=2e-4,
                                   atol=2e-4)


class TestTraining:
    def test_loss_decreases_on_fixed_batch(self):
        state = M.init_state(CFG, 0)
        toks = _tokens(CFG)
        ts = M.jit_train_step(CFG)
        state, ce0, _ = ts(state, toks)
        for _ in range(20):
            state, ce, _ = ts(state, toks)
        assert float(ce) < float(ce0) * 0.7

    def test_step_counter_increments(self):
        state = M.init_state(CFG, 0)
        toks = _tokens(CFG)
        state, *_ = M.train_step(CFG, state, toks)
        assert int(state[3]) == 1
        state, *_ = M.train_step(CFG, state, toks)
        assert int(state[3]) == 2

    def test_grad_then_apply_equals_train_step(self):
        state = M.init_state(CFG, 0)
        toks = _tokens(CFG)
        s1, ce1, _ = M.train_step(CFG, state, toks)
        grads, ce2, _ = M.grad_step(CFG, state[0], toks)
        s2 = M.apply_update(CFG, state, grads)
        assert float(ce1) == pytest.approx(float(ce2), rel=1e-6)
        worst = max(float(jnp.max(jnp.abs(s1[0][k] - s2[0][k])))
                    for k in s1[0])
        assert worst < 1e-6

    def test_adam_moments_updated(self):
        state = M.init_state(CFG, 0)
        toks = _tokens(CFG)
        s1, *_ = M.train_step(CFG, state, toks)
        m_norm = sum(float(jnp.sum(jnp.abs(v))) for v in s1[1].values())
        assert m_norm > 0


class TestConfig:
    def test_capacity_rounds_to_block(self):
        assert CFG.capacity % CFG.block_c == 0

    def test_validate_rejects_bad_heads(self):
        bad = M.ModelConfig(d_model=65, n_heads=2)
        with pytest.raises(ValueError):
            bad.validate()

    def test_validate_rejects_bad_topk(self):
        bad = M.ModelConfig(n_experts=4, top_k=8)
        with pytest.raises(ValueError):
            bad.validate()
