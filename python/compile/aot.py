"""AOT path: lower the L2 entrypoints to HLO *text* + a manifest for Rust.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage (from python/):
    python -m compile.aot --out ../artifacts --preset tiny
    python -m compile.aot --out ../artifacts --preset e2e
    python -m compile.aot --out ../artifacts --config my_model.json

Artifacts written:
    <out>/<preset>/init.hlo.txt          (seed u32[])            -> state
    <out>/<preset>/train_step.hlo.txt    (state, tokens)         -> state, ce, aux
    <out>/<preset>/grad_step.hlo.txt     (params, tokens)        -> grads, ce, aux
    <out>/<preset>/apply_update.hlo.txt  (state, grads)          -> state
    <out>/<preset>/forward.hlo.txt       (params, tokens[B,S])   -> logits, aux
    <out>/<preset>/manifest.json         shapes/dtypes/ordering + model config

State flat layout (everywhere, python and rust):
    [params (sorted by name), m (same order), v, step(i32 scalar)]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# Flat <-> pytree adapters (rust sees only flat tuples of arrays)
# --------------------------------------------------------------------------


def _pack(names, d):
    return tuple(d[k] for k in names)


def _unpack(names, flat):
    return dict(zip(names, flat))


def make_entrypoints(cfg: M.ModelConfig):
    """Flat-tuple versions of the model entrypoints, ready to lower."""
    names = M.param_names(cfg)
    p = len(names)

    def split_state(flat):
        params = _unpack(names, flat[:p])
        m = _unpack(names, flat[p:2 * p])
        v = _unpack(names, flat[2 * p:3 * p])
        step = flat[3 * p]
        return params, m, v, step

    def join_state(state):
        params, m, v, step = state
        return _pack(names, params) + _pack(names, m) + _pack(names, v) \
            + (step,)

    def init(seed):
        return join_state(M.init_state(cfg, seed))

    def train_step(*args):
        state = split_state(args[:3 * p + 1])
        tokens = args[3 * p + 1]
        new_state, ce, aux = M.train_step(cfg, state, tokens)
        return join_state(new_state) + (ce, aux)

    def grad_step(*args):
        params = _unpack(names, args[:p])
        tokens = args[p]
        grads, ce, aux = M.grad_step(cfg, params, tokens)
        return _pack(names, grads) + (ce, aux)

    def apply_update(*args):
        state = split_state(args[:3 * p + 1])
        grads = _unpack(names, args[3 * p + 1:4 * p + 1])
        return join_state(M.apply_update(cfg, state, grads))

    def forward(*args):
        params = _unpack(names, args[:p])
        tokens = args[p]
        logits, aux = M.forward(cfg, params, tokens)
        return logits, aux

    return {"init": init, "train_step": train_step, "grad_step": grad_step,
            "apply_update": apply_update, "forward": forward}


def _spec(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def example_args(cfg: M.ModelConfig, entry: str):
    """Abstract example arguments for lowering each entrypoint."""
    names = M.param_names(cfg)
    shapes = M.param_shapes(cfg)
    f32 = jnp.float32

    def arr(n):
        return jax.ShapeDtypeStruct(shapes[n], f32)

    params = [arr(n) for n in names]
    step = jax.ShapeDtypeStruct((), jnp.int32)
    tokens_tr = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    tokens_fw = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    state = params + params + params + [step]
    if entry == "init":
        return [jax.ShapeDtypeStruct((), jnp.uint32)]
    if entry == "train_step":
        return state + [tokens_tr]
    if entry == "grad_step":
        return params + [tokens_tr]
    if entry == "apply_update":
        return state + params
    if entry == "forward":
        return params + [tokens_fw]
    raise KeyError(entry)


def io_specs(cfg: M.ModelConfig, entry: str):
    """(inputs, outputs) manifest specs for an entrypoint."""
    names = M.param_names(cfg)
    shapes = M.param_shapes(cfg)
    pspecs = [_spec(n, shapes[n], "f32") for n in names]

    def sect(prefix):
        return [_spec(f"{prefix}.{n}", shapes[n], "f32") for n in names]

    step = _spec("step", (), "i32")
    scalar_f = lambda n: _spec(n, (), "f32")
    tokens_tr = _spec("tokens", (cfg.batch, cfg.seq_len + 1), "i32")
    tokens_fw = _spec("tokens", (cfg.batch, cfg.seq_len), "i32")
    state = sect("param") + sect("m") + sect("v") + [step]
    if entry == "init":
        return [_spec("seed", (), "u32")], state
    if entry == "train_step":
        return state + [tokens_tr], state + [scalar_f("ce"), scalar_f("aux")]
    if entry == "grad_step":
        return pspecs + [tokens_tr], sect("grad") + [scalar_f("ce"),
                                                     scalar_f("aux")]
    if entry == "apply_update":
        return state + sect("grad"), state
    if entry == "forward":
        logits = _spec("logits", (cfg.batch, cfg.seq_len, cfg.vocab), "f32")
        return pspecs + [tokens_fw], [logits, scalar_f("aux")]
    raise KeyError(entry)


PRESETS = {"tiny": M.TINY, "e2e": M.E2E}

DEFAULT_ENTRIES = ("init", "train_step", "grad_step", "apply_update",
                   "forward")


def build(cfg: M.ModelConfig, outdir: str, entries=DEFAULT_ENTRIES,
          verbose: bool = True) -> dict:
    cfg.validate()
    os.makedirs(outdir, exist_ok=True)
    eps = make_entrypoints(cfg)
    names = M.param_names(cfg)
    shapes = M.param_shapes(cfg)
    manifest = {
        "format": "hlo-text-v1",
        "config": cfg.to_dict(),
        "n_params": len(names),
        "total_param_elements": M.count_params(cfg),
        "param_names": names,
        "params": [_spec(n, shapes[n], "f32") for n in names],
        "state_layout": ["params", "m", "v", "step"],
        "entrypoints": {},
    }
    for entry in entries:
        t0 = time.time()
        lowered = jax.jit(eps[entry]).lower(*example_args(cfg, entry))
        text = to_hlo_text(lowered)
        fname = f"{entry}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as fh:
            fh.write(text)
        ins, outs = io_specs(cfg, entry)
        manifest["entrypoints"][entry] = {
            "file": fname, "inputs": ins, "outputs": outs}
        if verbose:
            print(f"  {entry:>13}: {len(text) / 1e6:.1f} MB HLO text "
                  f"({time.time() - t0:.1f}s)", flush=True)
    with open(os.path.join(outdir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default=None, choices=sorted(PRESETS),
                    action="append")
    ap.add_argument("--config", default=None,
                    help="JSON file with ModelConfig overrides")
    ap.add_argument("--entries", default=",".join(DEFAULT_ENTRIES))
    args = ap.parse_args()

    entries = tuple(e for e in args.entries.split(",") if e)
    jobs = []
    if args.config:
        with open(args.config) as fh:
            overrides = json.load(fh)
        name = overrides.pop("name", "custom")
        jobs.append((name, dataclasses.replace(M.ModelConfig(), **overrides)))
    for preset in (args.preset or (["tiny", "e2e"] if not args.config else [])):
        jobs.append((preset, PRESETS[preset]))

    for name, cfg in jobs:
        outdir = os.path.join(args.out, name)
        print(f"[aot] building '{name}' "
              f"({M.count_params(cfg) / 1e6:.1f}M params) -> {outdir}",
              flush=True)
        build(cfg, outdir, entries)
    print("[aot] done")


if __name__ == "__main__":
    main()
