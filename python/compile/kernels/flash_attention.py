"""L1 Pallas kernel: blocked causal attention with online softmax (flash).

The attention block is the second compute hot-spot of the paper's transformer
workload (§II.A: "compute ... dominated by the attention block and the FFN").
GPU flash-attention tiles Q over threadblocks and streams K/V through shared
memory; the TPU/Pallas rethink (DESIGN.md §Hardware-Adaptation):

- grid over ``(batch*heads, q_block)``; each step owns one MXU-shaped Q tile
  in VMEM and streams K/V tiles with a ``fori_loop`` *inside* the kernel —
  the HBM→VMEM schedule that threadblock software-pipelining does on GPU is
  expressed by the BlockSpec + in-kernel loop;
- the online-softmax carry (running max ``m``, normalizer ``l``, accumulator)
  never leaves VMEM;
- the forward kernel emits the log-sum-exp rows (FlashAttention-2 style) so
  the backward kernels can rematerialize probabilities tile-by-tile instead
  of storing the S×S matrix;
- backward is two Pallas kernels: dQ (grid over q blocks, loop over kv) and
  dK/dV (grid over kv blocks, loop over q), wired via ``custom_vjp``.

Lowered with ``interpret=True`` for CPU PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_q: int,
                block_k: int, seq_len: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # (bq, dh)
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    nk = seq_len // block_k

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], ki * block_k, block_k, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], ki * block_k, block_k, 0)
        s = jnp.dot(q, k.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, _NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    dh = q.shape[-1]
    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, acc0))
    # Every causal row attends at least to itself, so l > 0.
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[0] = m + jnp.log(l)


# --------------------------------------------------------------------------
# Backward
# --------------------------------------------------------------------------
# Notation (FlashAttention-2): S = scale·QKᵀ, P = exp(S − lse),
# delta_i = Σ_d dO_id · O_id, dS = P ∘ (dP − delta),
# dQ = scale · dS K, dK = scale · dSᵀ Q, dV = Pᵀ dO.


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   *, block_q: int, block_k: int, seq_len: int, scale: float,
                   causal: bool):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    nk = seq_len // block_k

    def body(ki, dq):
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], ki * block_k, block_k, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], ki * block_k, block_k, 0)
        s = scale * jnp.dot(q, k.astype(jnp.float32).T,
                            preferred_element_type=jnp.float32)
        if causal:
            k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, _NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jnp.dot(do, v.astype(jnp.float32).T,
                     preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        return dq + scale * jnp.dot(ds, k.astype(jnp.float32),
                                    preferred_element_type=jnp.float32)

    dq0 = jnp.zeros_like(q)
    dq_ref[0] = jax.lax.fori_loop(0, nk, body, dq0).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, block_q: int, block_k: int,
                    seq_len: int, scale: float, causal: bool):
    ki = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)                     # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
    nq = seq_len // block_q

    def body(qi, carry):
        dk, dv = carry
        q = jax.lax.dynamic_slice_in_dim(q_ref[0], qi * block_q, block_q, 0)
        do = jax.lax.dynamic_slice_in_dim(do_ref[0], qi * block_q, block_q, 0)
        lse = jax.lax.dynamic_slice_in_dim(lse_ref[0], qi * block_q, block_q, 0)
        delta = jax.lax.dynamic_slice_in_dim(delta_ref[0], qi * block_q,
                                             block_q, 0)
        q = q.astype(jnp.float32)
        do = do.astype(jnp.float32)
        s = scale * jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
            s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, _NEG_INF)
        p = jnp.exp(s - lse[:, None])                    # (bq, bk)
        dv = dv + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk = dk + scale * jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
        return dk, dv

    dh = k.shape[-1]
    z = jnp.zeros((k.shape[0], dh), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, nq, body, (z, z))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


# --------------------------------------------------------------------------
# custom_vjp wiring
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build(block_q: int, block_k: int, causal: bool, scale: float,
           interpret: bool):
    def fwd_call(q, k, v):
        bh, s, dh = q.shape
        kern = functools.partial(_fwd_kernel, block_q=block_q,
                                 block_k=block_k, seq_len=s, scale=scale,
                                 causal=causal)
        return pl.pallas_call(
            kern,
            grid=(bh, s // block_q),
            in_specs=[
                pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
                pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
                pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
                pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
                jax.ShapeDtypeStruct((bh, s), jnp.float32),
            ],
            interpret=interpret,
        )(q, k, v)

    def bwd_call(q, k, v, o, lse, do):
        bh, s, dh = q.shape
        delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)
        dq_kern = functools.partial(_bwd_dq_kernel, block_q=block_q,
                                    block_k=block_k, seq_len=s, scale=scale,
                                    causal=causal)
        full = lambda b, i: (b, 0, 0)
        full1 = lambda b, i: (b, 0)
        dq = pl.pallas_call(
            dq_kern,
            grid=(bh, s // block_q),
            in_specs=[
                pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
                pl.BlockSpec((1, s, dh), full),
                pl.BlockSpec((1, s, dh), full),
                pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
                pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
                pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
            ],
            out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
            out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
            interpret=interpret,
        )(q, k, v, do, lse, delta)

        dkv_kern = functools.partial(_bwd_dkv_kernel, block_q=block_q,
                                     block_k=block_k, seq_len=s, scale=scale,
                                     causal=causal)
        dk, dv = pl.pallas_call(
            dkv_kern,
            grid=(bh, s // block_k),
            in_specs=[
                pl.BlockSpec((1, s, dh), full),
                pl.BlockSpec((1, block_k, dh), lambda b, i: (b, i, 0)),
                pl.BlockSpec((1, block_k, dh), lambda b, i: (b, i, 0)),
                pl.BlockSpec((1, s, dh), full),
                pl.BlockSpec((1, s), full1),
                pl.BlockSpec((1, s), full1),
            ],
            out_specs=[
                pl.BlockSpec((1, block_k, dh), lambda b, i: (b, i, 0)),
                pl.BlockSpec((1, block_k, dh), lambda b, i: (b, i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((bh, s, dh), k.dtype),
                jax.ShapeDtypeStruct((bh, s, dh), v.dtype),
            ],
            interpret=interpret,
        )(q, k, v, do, lse, delta)
        return dq, dk, dv

    @jax.custom_vjp
    def f(q, k, v):
        o, _ = fwd_call(q, k, v)
        return o

    def f_fwd(q, k, v):
        o, lse = fwd_call(q, k, v)
        return o, (q, k, v, o, lse)

    def f_bwd(res, do):
        q, k, v, o, lse = res
        return bwd_call(q, k, v, o, lse, do)

    f.defvjp(f_fwd, f_bwd)
    return f


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 64,
                    block_k: int = 64, scale: float | None = None,
                    interpret: bool = True):
    """Blocked attention: softmax(scale · q kᵀ + mask) v, per (batch, head).

    Differentiable (custom Pallas backward kernels, FA-2 recomputation).

    Args:
      q, k, v: f32[BH, S, Dh] — batch and heads pre-flattened.
      causal: apply lower-triangular mask.
      block_q, block_k: tile sizes; S must be a multiple of both.

    Returns f32[BH, S, Dh].
    """
    bh, s, dh = q.shape
    if k.shape != (bh, s, dh) or v.shape != (bh, s, dh):
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} not a multiple of blocks {block_q}/{block_k}")
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    return _build(block_q, block_k, causal, float(scale), interpret)(q, k, v)


def vmem_bytes(block_q: int, block_k: int, s: int, dh: int,
               dtype_bytes: int = 4) -> int:
    """Static VMEM footprint for one fwd grid step (perf model input)."""
    return dtype_bytes * (
        block_q * dh            # q tile
        + 2 * s * dh            # k, v panels (streamed but resident here)
        + block_q * block_k     # scores tile
        + block_q * dh          # accumulator
        + block_q * dh          # output
        + 2 * block_q           # m, l carries
    )
