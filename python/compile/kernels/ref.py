"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel configuration exercised
by pytest (including hypothesis shape/dtype sweeps) is checked allclose
against these reference implementations, and the L2 model can be built
against either implementation (``use_pallas`` flag) so the whole train step
has a kernel-free oracle too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_ffn_ref(x_dispatch, w1, b1, w2, b2):
    """Grouped expert FFN, einsum form. Shapes as kernels.moe_ffn."""
    h = jnp.einsum("ecd,edf->ecf", x_dispatch, w1) + b1[:, None, :]
    h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, w2) + b2[:, None, :]


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Dense softmax attention over f32[BH, S, Dh]."""
    bh, s, dh = q.shape
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    scores = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32)).astype(q.dtype)
