"""L1 Pallas kernel: grouped (per-expert) FFN over capacity-dispatched tokens.

This is the MoE compute hot-spot of the paper's workload: every token that the
router assigned to expert ``e`` flows through that expert's two-matmul FFN.
On GPUs this is a grouped GEMM over threadblocks with shared-memory weight
staging; the TPU/Pallas rethink (DESIGN.md §Hardware-Adaptation):

- grid over ``(expert, token_block)`` — expert-major iteration keeps one
  expert's weight panels VMEM-resident across all of its token blocks (the
  scratchpad analogue of shared-memory staging);
- both matmuls are fused in a single kernel so the ``(block, d_ff)``
  intermediate never round-trips to HBM;
- tiles are MXU-shaped: ``block_c`` and all feature dims should be multiples
  of 128 on real hardware (pad upstream if needed).

The backward pass is also written as a Pallas kernel (grid over the same
(expert, token-block) schedule, with weight-gradient accumulation across
token blocks via output-block revisiting) and wired up with ``custom_vjp``
— JAX in this image cannot autodiff through ``pallas_call``.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls); real-TPU efficiency is estimated from `vmem_bytes` /
`mxu_flops` in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --------------------------------------------------------------------------
# Forward kernel
# --------------------------------------------------------------------------


def _fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One (expert, token-block) step.

    x: (1, bc, d)  w1: (1, d, f)  b1: (1, f)  w2: (1, f, d)  b2: (1, d)
    o: (1, bc, d)
    """
    x = x_ref[0]
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32) + b1_ref[0]
    h = jax.nn.gelu(h)
    y = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32) + b2_ref[0]
    o_ref[0] = y.astype(o_ref.dtype)


# --------------------------------------------------------------------------
# Backward kernel
# --------------------------------------------------------------------------


def _bwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, dy_ref,
                dx_ref, dw1_ref, db1_ref, dw2_ref, db2_ref):
    """Backward for one (expert, token-block) step.

    Recomputes the FFN intermediate (flash-style rematerialization: the
    (bc, f) activation never lived in HBM) and accumulates weight grads
    across token blocks by revisiting the per-expert output block — the grid
    is sequential in Pallas semantics, so `+=` accumulation is well-defined.
    """
    ci = pl.program_id(1)
    x = x_ref[0]
    dy = dy_ref[0]
    w1 = w1_ref[0]
    w2 = w2_ref[0]

    s = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1_ref[0]
    h, gelu_vjp = jax.vjp(jax.nn.gelu, s)
    dh = jnp.dot(dy, w2.T, preferred_element_type=jnp.float32)
    (ds,) = gelu_vjp(dh)

    dx_ref[0] = jnp.dot(ds, w1.T, preferred_element_type=jnp.float32)

    @pl.when(ci == 0)
    def _init():
        dw1_ref[0] = jnp.zeros_like(dw1_ref[0])
        db1_ref[0] = jnp.zeros_like(db1_ref[0])
        dw2_ref[0] = jnp.zeros_like(dw2_ref[0])
        db2_ref[0] = jnp.zeros_like(db2_ref[0])

    dw1_ref[0] += jnp.dot(x.T, ds, preferred_element_type=jnp.float32)
    db1_ref[0] += jnp.sum(ds, axis=0)
    dw2_ref[0] += jnp.dot(h.T, dy, preferred_element_type=jnp.float32)
    db2_ref[0] += jnp.sum(dy, axis=0)


# --------------------------------------------------------------------------
# custom_vjp wiring
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _build(block_c: int, interpret: bool):
    """One differentiable grouped-FFN callable per tile configuration."""

    def fwd_call(x, w1, b1, w2, b2):
        e, c, d = x.shape
        f = w1.shape[2]
        grid = (e, c // block_c)
        return pl.pallas_call(
            _fwd_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
                pl.BlockSpec((1, d, f), lambda ei, ci: (ei, 0, 0)),
                pl.BlockSpec((1, f), lambda ei, ci: (ei, 0)),
                pl.BlockSpec((1, f, d), lambda ei, ci: (ei, 0, 0)),
                pl.BlockSpec((1, d), lambda ei, ci: (ei, 0)),
            ],
            out_specs=pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
            out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
            interpret=interpret,
        )(x, w1, b1, w2, b2)

    def bwd_call(x, w1, b1, w2, dy):
        e, c, d = x.shape
        f = w1.shape[2]
        grid = (e, c // block_c)
        return pl.pallas_call(
            _bwd_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
                pl.BlockSpec((1, d, f), lambda ei, ci: (ei, 0, 0)),
                pl.BlockSpec((1, f), lambda ei, ci: (ei, 0)),
                pl.BlockSpec((1, f, d), lambda ei, ci: (ei, 0, 0)),
                pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, block_c, d), lambda ei, ci: (ei, ci, 0)),
                pl.BlockSpec((1, d, f), lambda ei, ci: (ei, 0, 0)),
                pl.BlockSpec((1, f), lambda ei, ci: (ei, 0)),
                pl.BlockSpec((1, f, d), lambda ei, ci: (ei, 0, 0)),
                pl.BlockSpec((1, d), lambda ei, ci: (ei, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((e, c, d), x.dtype),
                jax.ShapeDtypeStruct(w1.shape, w1.dtype),
                jax.ShapeDtypeStruct((e, f), w1.dtype),
                jax.ShapeDtypeStruct(w2.shape, w2.dtype),
                jax.ShapeDtypeStruct((e, d), w2.dtype),
            ],
            interpret=interpret,
        )(x, w1, b1, w2, dy)

    @jax.custom_vjp
    def f(x, w1, b1, w2, b2):
        return fwd_call(x, w1, b1, w2, b2)

    def f_fwd(x, w1, b1, w2, b2):
        return fwd_call(x, w1, b1, w2, b2), (x, w1, b1, w2)

    def f_bwd(res, dy):
        x, w1, b1, w2 = res
        dx, dw1, db1, dw2, db2 = bwd_call(x, w1, b1, w2, dy)
        return dx, dw1, db1, dw2, db2

    f.defvjp(f_fwd, f_bwd)
    return f


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------


def moe_ffn(x_dispatch, w1, b1, w2, b2, *, block_c: int = 128,
            interpret: bool = True):
    """Grouped expert FFN: ``y[e,c] = gelu(x[e,c] @ w1[e] + b1[e]) @ w2[e] + b2[e]``.

    Differentiable (custom Pallas backward kernel).

    Args:
      x_dispatch: f32[E, C, D] capacity-dispatched tokens (zeros in unused
        capacity slots — GShard-style dense dispatch).
      w1: f32[E, D, F]; b1: f32[E, F]; w2: f32[E, F, D]; b2: f32[E, D].
      block_c: token-block (capacity) tile; C must be a multiple of it.
      interpret: lower through the Pallas interpreter (required on CPU).

    Returns: f32[E, C, D].
    """
    e, c, d = x_dispatch.shape
    f = w1.shape[2]
    if w1.shape != (e, d, f):
        raise ValueError(f"w1 shape {w1.shape} != {(e, d, f)}")
    if w2.shape != (e, f, d):
        raise ValueError(f"w2 shape {w2.shape} != {(e, f, d)}")
    if b1.shape != (e, f) or b2.shape != (e, d):
        raise ValueError(f"bias shapes {b1.shape} {b2.shape}")
    if c % block_c != 0:
        raise ValueError(f"capacity {c} not a multiple of block_c {block_c}")
    return _build(block_c, interpret)(x_dispatch, w1, b1, w2, b2)


def vmem_bytes(block_c: int, d: int, f: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one fwd grid step (perf model)."""
    return dtype_bytes * (
        block_c * d          # x tile
        + d * f + f          # w1 + b1
        + f * d + d          # w2 + b2
        + block_c * f        # intermediate h
        + block_c * d        # output tile
    )


def mxu_flops(e: int, c: int, d: int, f: int) -> int:
    """Total MAC-flops issued to the MXU for one fwd invocation."""
    return 2 * e * c * (d * f + f * d)
