"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from compile.kernels.flash_attention import flash_attention
from compile.kernels.moe_ffn import moe_ffn

__all__ = ["flash_attention", "moe_ffn"]
