"""L2: MoE decoder transformer (fwd/bwd/Adam) in JAX, calling the L1 kernels.

This is the workload of the paper (§II.A, Fig. 1b): a GPT-style decoder
stack where every layer's FFN is replaced by a top-k routed bank of
fine-grained experts. The same architecture family the paper costs
analytically at 4.7 T parameters is instantiated here at ~100 M parameters
for the end-to-end driver (examples/train_moe.rs).

Everything here is build-time Python: `aot.py` lowers the entrypoints to HLO
text once, and the Rust coordinator executes them via PJRT. Python is never
on the training path.

Entry points (all pure, pytree-in/pytree-out; aot.py flattens them):
  init_state(cfg)(seed)                  -> state
  train_step(cfg)(state, tokens)        -> state', (loss, aux)
  grad_step(cfg)(params, tokens)        -> grads, (loss, aux)
  apply_update(cfg)(state, grads)       -> state'
  forward(cfg)(params, tokens)          -> logits
where state = (params, m, v, step) and tokens is i32[B, S+1].
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import flash_attention, moe_ffn
from compile.kernels import ref as kref

Params = Dict[str, jax.Array]


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """MoE transformer hyperparameters.

    ``d_ff`` is the hidden dim of each (already fine-grained) expert: in the
    paper's notation an original expert with hidden ``d_ff0`` split at
    granularity ``m`` yields experts with ``d_ff = d_ff0 / m`` — the split is
    applied by the caller (see presets / rust `config` module).
    """

    vocab: int = 8192
    d_model: int = 512
    n_layers: int = 8
    n_heads: int = 8
    d_ff: int = 1408
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.5
    seq_len: int = 128
    batch: int = 2
    aux_weight: float = 1e-2
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    # Kernel tiling (see kernels/*.py); must divide the respective dims.
    use_pallas: bool = True
    block_c: int = 128
    block_q: int = 64
    block_k: int = 64

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_tokens(self) -> int:
        return self.batch * self.seq_len

    @property
    def capacity(self) -> int:
        """Per-expert token capacity, rounded up to the kernel tile."""
        raw = math.ceil(self.n_tokens / self.n_experts
                        * self.top_k * self.capacity_factor)
        return ((raw + self.block_c - 1) // self.block_c) * self.block_c

    def validate(self) -> None:
        if self.d_model % self.n_heads:
            raise ValueError("d_model must divide into n_heads")
        if self.seq_len % self.block_q or self.seq_len % self.block_k:
            raise ValueError("seq_len must be a multiple of block_q/block_k")
        if self.top_k > self.n_experts:
            raise ValueError("top_k > n_experts")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


TINY = ModelConfig(vocab=128, d_model=64, n_layers=2, n_heads=2, d_ff=128,
                   n_experts=4, top_k=2, seq_len=32, batch=2,
                   block_c=16, block_q=16, block_k=16)

# ~105 M parameters: the end-to-end driver config (EXPERIMENTS.md §E2E).
# block_q = block_k = seq_len collapses each flash grid row to a single
# interpreter step (§Perf-L1: interpret-mode cost scales with grid steps,
# and a 128x64 Q tile still fits VMEM comfortably on real hardware).
E2E = ModelConfig(block_q=128, block_k=128)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Canonical (ordered) name -> shape map. The AOT manifest and the Rust
    runtime both key off this ordering (sorted by name)."""
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    shapes: Dict[str, Tuple[int, ...]] = {
        "tok_emb": (cfg.vocab, d),
        "pos_emb": (cfg.seq_len, d),
        "ln_f.g": (d,),
        "ln_f.b": (d,),
    }
    for i in range(cfg.n_layers):
        p = f"layer{i:02d}."
        shapes[p + "ln1.g"] = (d,)
        shapes[p + "ln1.b"] = (d,)
        shapes[p + "attn.wq"] = (d, d)
        shapes[p + "attn.wk"] = (d, d)
        shapes[p + "attn.wv"] = (d, d)
        shapes[p + "attn.wo"] = (d, d)
        shapes[p + "ln2.g"] = (d,)
        shapes[p + "ln2.b"] = (d,)
        shapes[p + "router.w"] = (d, e)
        shapes[p + "moe.w1"] = (e, d, f)
        shapes[p + "moe.b1"] = (e, f)
        shapes[p + "moe.w2"] = (e, f, d)
        shapes[p + "moe.b2"] = (e, d)
    return shapes


def param_names(cfg: ModelConfig) -> list[str]:
    """Deterministic flattening order used everywhere (python and rust)."""
    return sorted(param_shapes(cfg))


def count_params(cfg: ModelConfig) -> int:
    return sum(math.prod(s) for s in param_shapes(cfg).values())


def init_params(cfg: ModelConfig, seed) -> Params:
    """Initialize parameters from a (traced or concrete) uint32 seed."""
    key = jax.random.PRNGKey(seed)
    shapes = param_shapes(cfg)
    params: Params = {}
    for i, name in enumerate(sorted(shapes)):
        shape = shapes[name]
        k = jax.random.fold_in(key, i)
        if name.endswith((".g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".b", ".b1", ".b2")) or name.endswith("moe.b1") \
                or name.endswith("moe.b2"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / math.sqrt(fan_in)
            if name.endswith("attn.wo") or name.endswith("moe.w2"):
                # GPT-2 style residual-branch scaling.
                std /= math.sqrt(2.0 * cfg.n_layers)
            params[name] = std * jax.random.normal(k, shape, jnp.float32)
    return params


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(cfg: ModelConfig, p: Params, prefix: str, x):
    """Multi-head causal self-attention over x: f32[B, S, D]."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def split(y):  # [B,S,D] -> [B*H, S, Dh]
        return (y.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
                .reshape(b * h, s, dh))

    q = split(x @ p[prefix + "attn.wq"])
    k = split(x @ p[prefix + "attn.wk"])
    v = split(x @ p[prefix + "attn.wv"])
    if cfg.use_pallas:
        o = flash_attention(q, k, v, causal=True,
                            block_q=cfg.block_q, block_k=cfg.block_k)
    else:
        o = kref.attention_ref(q, k, v, causal=True)
    o = (o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d))
    return o @ p[prefix + "attn.wo"]


def _topk(probs, k: int):
    """Iterative-argmax top-k.

    Equivalent to ``jax.lax.top_k`` (incl. lowest-index tie-breaking) but
    lowers to reduce/select ops: the dedicated ``topk`` HLO instruction that
    lax.top_k emits post-dates the xla_extension 0.5.1 parser used by the
    Rust runtime (see aot.py header).
    """
    vals, idxs = [], []
    masked = probs
    for _ in range(k):
        idx = jnp.argmax(masked, axis=-1)                       # [N]
        val = jnp.take_along_axis(probs, idx[:, None], -1)[:, 0]
        vals.append(val)
        idxs.append(idx)
        hit = jax.nn.one_hot(idx, probs.shape[-1], dtype=jnp.bool_)
        masked = jnp.where(hit, -jnp.inf, masked)
    return jnp.stack(vals, -1), jnp.stack(idxs, -1)


def _route(cfg: ModelConfig, logits):
    """Top-k routing with per-expert capacity (GShard-style dense dispatch).

    Args:   logits f32[N, E].
    Returns (dispatch f32[N, E, C], combine f32[N, E, C], aux f32[], stats).
    """
    n, e = logits.shape
    c = cfg.capacity
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate_vals, expert_idx = _topk(probs, cfg.top_k)             # [N, k]
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    dispatch = jnp.zeros((n, e, c), jnp.float32)
    combine = jnp.zeros((n, e, c), jnp.float32)
    counts = jnp.zeros((e,), jnp.int32)
    dropped = jnp.zeros((), jnp.int32)
    for slot in range(cfg.top_k):
        mask_e = jax.nn.one_hot(expert_idx[:, slot], e, dtype=jnp.int32)
        # Position of each token in its expert's queue (earlier slots and
        # earlier tokens first), GShard cumsum trick.
        pos_in_e = jnp.cumsum(mask_e, axis=0) - 1 + counts[None, :]  # [N,E]
        loc = jnp.sum(mask_e * pos_in_e, -1)                          # [N]
        counts = counts + jnp.sum(mask_e, 0)
        keep = loc < c
        dropped = dropped + jnp.sum(~keep)
        sel = (jax.nn.one_hot(expert_idx[:, slot], e, dtype=jnp.float32)
               [:, :, None]
               * jax.nn.one_hot(jnp.where(keep, loc, 0), c,
                                dtype=jnp.float32)[:, None, :]
               * keep[:, None, None].astype(jnp.float32))
        dispatch = dispatch + sel
        combine = combine + sel * gate_vals[:, slot][:, None, None]

    # Switch-transformer load-balance loss on first-choice assignment.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return dispatch, combine, aux, {"dropped": dropped, "counts": counts}


def _moe_layer(cfg: ModelConfig, p: Params, prefix: str, x):
    """Routed fine-grained expert FFN over x: f32[B, S, D]."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    logits = xf @ p[prefix + "router.w"]
    dispatch, combine, aux, _ = _route(cfg, logits)
    xd = jnp.einsum("nec,nd->ecd", dispatch, xf)                 # [E, C, D]
    if cfg.use_pallas:
        ye = moe_ffn(xd, p[prefix + "moe.w1"], p[prefix + "moe.b1"],
                     p[prefix + "moe.w2"], p[prefix + "moe.b2"],
                     block_c=cfg.block_c)
    else:
        ye = kref.moe_ffn_ref(xd, p[prefix + "moe.w1"], p[prefix + "moe.b1"],
                              p[prefix + "moe.w2"], p[prefix + "moe.b2"])
    y = jnp.einsum("nec,ecd->nd", combine, ye)
    return y.reshape(b, s, d), aux


def forward(cfg: ModelConfig, p: Params, tokens):
    """Logits for next-token prediction. tokens: i32[B, S] -> f32[B, S, V]."""
    b, s = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(cfg.n_layers):
        pre = f"layer{i:02d}."
        x = x + _attention(cfg, p, pre,
                           _layer_norm(x, p[pre + "ln1.g"], p[pre + "ln1.b"]))
        y, aux = _moe_layer(cfg, p, pre,
                            _layer_norm(x, p[pre + "ln2.g"], p[pre + "ln2.b"]))
        x = x + y
        aux_total = aux_total + aux
    x = _layer_norm(x, p["ln_f.g"], p["ln_f.b"])
    logits = x @ p["tok_emb"].T          # weight-tied LM head
    return logits, aux_total / cfg.n_layers


def loss_fn(cfg: ModelConfig, p: Params, tokens):
    """tokens: i32[B, S+1] -> (total_loss, (ce, aux))."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits, aux = forward(cfg, p, inp)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + cfg.aux_weight * aux, (ce, aux)


# --------------------------------------------------------------------------
# Optimizer (Adam) and entry points
# --------------------------------------------------------------------------


def zeros_like_params(cfg: ModelConfig) -> Params:
    return {k: jnp.zeros(s, jnp.float32)
            for k, s in param_shapes(cfg).items()}


def init_state(cfg: ModelConfig, seed):
    p = init_params(cfg, seed)
    z = {k: jnp.zeros_like(v) for k, v in p.items()}
    zv = {k: jnp.zeros_like(v) for k, v in p.items()}
    return p, z, zv, jnp.zeros((), jnp.int32)


def grad_step(cfg: ModelConfig, p: Params, tokens):
    (loss, (ce, aux)), grads = jax.value_and_grad(
        lambda q: loss_fn(cfg, q, tokens), has_aux=True)(p)
    return grads, ce, aux


def apply_update(cfg: ModelConfig, state, grads):
    p, m, v, step = state
    step = step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.beta1 ** t
    bc2 = 1.0 - cfg.beta2 ** t
    new_p, new_m, new_v = {}, {}, {}
    for k in p:
        g = grads[k]
        new_m[k] = cfg.beta1 * m[k] + (1 - cfg.beta1) * g
        new_v[k] = cfg.beta2 * v[k] + (1 - cfg.beta2) * g * g
        mhat = new_m[k] / bc1
        vhat = new_v[k] / bc2
        new_p[k] = p[k] - cfg.lr * mhat / (jnp.sqrt(vhat) + cfg.eps)
    return new_p, new_m, new_v, step


def train_step(cfg: ModelConfig, state, tokens):
    p = state[0]
    grads, ce, aux = grad_step(cfg, p, tokens)
    new_state = apply_update(cfg, state, grads)
    return new_state, ce, aux


# Jitted pytree-level wrappers for python-side tests.
def jit_train_step(cfg: ModelConfig):
    return jax.jit(functools.partial(train_step, cfg))


def jit_loss(cfg: ModelConfig):
    return jax.jit(functools.partial(loss_fn, cfg))
